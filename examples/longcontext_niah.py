"""Long-context retrieval: watch the MoBA router find a planted needle,
and see block size + key convolution change retrieval accuracy exactly as
the SNR theory predicts.

    PYTHONPATH=src python examples/longcontext_niah.py
"""
import os
import sys

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_root, "src"))
sys.path.insert(0, _root)

from benchmarks.table34_niah import run as run_niah
from repro.core import snr

print("theory: p_fail = Φ(−Δμ_eff·sqrt(d/2B))  → smaller B retrieves "
      "better;\nclustering (kconv) raises Δμ_eff.\n")
for bs in (256, 128, 64):
    print(f"  B={bs:4d}: predicted per-pair p_fail ="
          f" {snr.p_fail(64, bs, 0.5):.4f}")
print()
run_niah(lengths=(1024, 2048, 4096), trials=40)
