"""Serving example: batched prefill + greedy decode with MoBA KV routing.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b

Pick the attention implementation end-to-end with --attn-backend
(reference | xla | flash | ..., see repro.core.backends):

    PYTHONPATH=src python examples/serve_decode.py --attn-backend flash
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--attn-backend", default="reference",
                    help="registered attention backend (core.backends); "
                         "Pallas backends take an option suffix, e.g. "
                         "flash:compiled or flash:flat")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill token budget per engine step "
                         "(0 = whole-prompt prefill)")
    args = ap.parse_args()
    toks = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                 gen=args.gen, smoke=True, attn_backend=args.attn_backend,
                 prefill_chunk=args.prefill_chunk)
    print("generated token ids (greedy):")
    print(toks)


if __name__ == "__main__":
    main()
