"""End-to-end training example: the paper's 340M hybrid (SWA/MoBA) recipe
at CPU-runnable scale, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py            # smoke scale
    PYTHONPATH=src python examples/train_lm.py --full     # full 340M cfg

Compares MoBA against the dense baseline over a few hundred steps on the
synthetic Markov corpus — the Table 1 protocol in miniature.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use the real 340M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    print("== MoBA (B=16, k=2 smoke) ==")
    _, moba_losses = train("moba-340m", steps=args.steps, batch=4,
                           seq=256, smoke=not args.full,
                           attn_backend="sparse", lr=3e-3,
                           ckpt_dir="/tmp/moba_train_example",
                           resume="auto", save_interval=25)
    print(f"final loss: {moba_losses[-1]:.4f} "
          f"(start {moba_losses[0]:.4f})")


if __name__ == "__main__":
    main()
