"""Quickstart: MoBA attention in three flavors + the SNR design rule.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba, snr
from repro.kernels import ops, ref

B, H, HKV, N, D = 1, 4, 2, 512, 64
cfg = MoBAConfig(block_size=64, top_k=2)

keys = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(keys[0], (B, H, N, D), jnp.float32) * 0.5
k = jax.random.normal(keys[1], (B, HKV, N, D), jnp.float32) * 0.5
v = jax.random.normal(keys[2], (B, HKV, N, D), jnp.float32)

# 1) reference (O(N^2) oracle)
o_ref = moba.moba_attention_reference(q, k, v, cfg)
# 2) production XLA gather-and-densify
o_xla = ref.moba_sparse_xla(q, k, v, cfg)
# 3) FlashMoBA Pallas kernels (interpret mode on CPU; TPU target)
o_ker = ops.flash_moba(q, k, v, cfg)

print("reference vs sparse-XLA max err:",
      float(jnp.abs(o_ref - o_xla).max()))
print("reference vs Pallas kernel max err:",
      float(jnp.abs(o_ref - o_ker).max()))

# routing: which blocks does query 300 attend to?
sel = moba.moba_selection(q, k, cfg)
print(f"query 300 (block {300 // 64}) selects blocks:",
      np.asarray(sel[0, 0, 300]))

# the paper's design rule: SNR = Δμ_eff · sqrt(d / 2B)
for bs in (512, 256, 128):
    s = snr.snr(64, bs, 0.5)
    print(f"B={bs:4d}: SNR={s:.3f}  p_fail={snr.p_fail(64, bs, 0.5):.3f}")
print("halving B buys sqrt(2) SNR — hence FlashMoBA for small blocks.")
