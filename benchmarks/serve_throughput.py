"""Serving-engine throughput: continuous batching vs fixed-batch loop.

Rows: decode tokens/s and per-step prefill/decode latency for the paged
engine across batch sizes, against the legacy lockstep loop on the same
workload.  Derived column = tokens/s (engine rows additionally carry
ttft_p50 for the stream row).

``--shards N`` instead benchmarks the sharded engine (one shard_map
decode across N page-pool shards) on a Poisson stream and reports
per-shard tokens/s plus p50/p99 TTFT and end-to-end latency.  Devices
are simulated on the host platform when fewer than N are visible, so
the flag works on a laptop (throughput numbers are then about dispatch
overheads, not real parallel speedup).

``--json out.json`` (optionally with ``--smoke``) instead runs the
radix-tree prefix-cache traces: synthetic request streams sharing a
system-prompt prefix (page-aligned and misaligned variants) plus an
undersized-pool preemption trace, each served twice — ``prefix_off`` vs
``prefix_on`` — with greedy tokens compared for exactness.  The report
uses the same stable machine-readable schema style as
``decode_micro.py`` (schema_version, named cases, a top-level ``agree``
verdict, nonzero exit on disagreement) and is consumed by the CI
``bench-smoke`` leg via ``check_regression.py``: per-case ``metrics``
carry ``tokens_per_s``, ``latency_p50_ms`` / ``latency_p99_ms``,
``prefix_hit_rate``, ``prefill_tokens_saved``, ``speedup`` and
``pages_in_use_peak``.  Wall-time-derived numbers are informational on
CPU; the gated signals are exactness, the hit/saved rates (pure
scheduler accounting) and the within-run on/off speedup ratio.

``--traces open-loop`` selects the staged-API open-loop traces instead
(the CI ``serve-smoke`` leg): requests arrive on a fixed decode-step
schedule through ``serving.frontend.run_open_loop`` with dispatch-ahead
decode, token-compared against the legacy closed loop on the identical
workload.  Per-case ``metrics`` carry ``sustained_tokens_per_s``,
``ttft_p50_ms`` / ``ttft_p99_ms``, ``tpot_p50_ms`` / ``tpot_p99_ms``,
``dispatch_depth_peak`` and ``preemptions``; the within-run gates are
exactness + pipeline depth (+ preemptions on undersized pools), and
``check_regression.py`` holds the wall-derived numbers only to loose
cross-machine bands (tokens/s floor, TTFT ceiling).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

ARCH = "moba-340m"
PROMPT, GEN = 48, 24

SCHEMA_VERSION = 1

# prefix-cache traces: n requests share a prefix_len-token system
# prompt, each with a distinct 1..sfx-token user suffix.  max_seqs
# staggers admission (later waves hit the cache); num_pages=0 means a
# fully provisioned pool, nonzero undersizes it to force preemption.
SMOKE_TRACES = [
    dict(kind="prefix_aligned", n=10, prefix_len=96, sfx=8, gen=4,
         max_seqs=2, num_pages=0),
    dict(kind="prefix_misaligned", n=10, prefix_len=101, sfx=8, gen=4,
         max_seqs=2, num_pages=0),
    dict(kind="preempt_swap", n=6, prefix_len=96, sfx=8, gen=16,
         max_seqs=4, num_pages=22),
]
FULL_TRACES = SMOKE_TRACES + [
    dict(kind="prefix_aligned", n=64, prefix_len=2048, sfx=16, gen=8,
         max_seqs=4, num_pages=0),
    dict(kind="prefix_misaligned", n=64, prefix_len=2053, sfx=16, gen=8,
         max_seqs=4, num_pages=0),
]

# open-loop traces: requests arrive every ``every`` decode steps via the
# staged API (serving.frontend.run_open_loop) with dispatch-ahead
# decode; the gated signals are token-exactness vs the legacy closed
# loop on the identical workload and pipeline-depth evidence that
# dispatch-ahead engaged (both within-run, machine-independent).
# ``num_pages`` nonzero undersizes the pool so admission + preemption
# replay happen mid-pipeline.
OPEN_LOOP_SMOKE = [
    dict(kind="open_loop", n=8, plen=40, sfx=8, gen=8, every=2,
         max_seqs=4, num_pages=0, dispatch_ahead=1),
    dict(kind="open_loop_preempt", n=6, plen=40, sfx=8, gen=12, every=2,
         max_seqs=2, num_pages=6, dispatch_ahead=2),
]
OPEN_LOOP_FULL = OPEN_LOOP_SMOKE + [
    dict(kind="open_loop", n=24, plen=96, sfx=16, gen=16, every=3,
         max_seqs=8, num_pages=0, dispatch_ahead=2),
]


def _engine_row(batch: int):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig

    cfg = get_smoke_config(ARCH)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=batch, max_seq_len=PROMPT + GEN + 8,
        max_prefill_batch=min(batch, 4)))
    for i in range(batch):
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT, dtype=np.int32),
                   max_new_tokens=GEN)
    eng.run()   # includes compile; counters below reflect full wall time
    st = eng.stats
    dec_us = st["decode_s"] / max(st["decode_steps"], 1) * 1e6
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    pre_us = st["prefill_s"] / max(st["prefill_tokens"], 1) * 1e6
    return [(f"serve_engine_b{batch}_decode_step", dec_us,
             f"{tps:.1f} tok/s"),
            (f"serve_engine_b{batch}_prefill_per_tok", pre_us, "")]


def _fixed_row(batch: int):
    from repro.launch.serve import serve_fixed

    t0 = time.perf_counter()
    serve_fixed(ARCH, batch=batch, prompt_len=PROMPT, gen=GEN, smoke=True)
    wall = time.perf_counter() - t0
    tps = batch * GEN / wall
    return [(f"serve_fixed_b{batch}_total", wall * 1e6 / (batch * GEN),
             f"{tps:.1f} tok/s")]


def bench():
    rows = []
    for batch in (2, 4, 8):
        rows.extend(_engine_row(batch))
        rows.extend(_fixed_row(batch))
    # continuous-batching scenario the fixed loop cannot express:
    # staggered Poisson arrivals with mixed prompt/gen lengths
    from repro.launch.serve import serve_stream
    m = serve_stream(ARCH, n_requests=8, rate=100.0, max_seqs=4,
                     prompt_range=(16, 48), gen_range=(8, 24),
                     smoke=True, realtime=False)
    rows.append(("serve_stream_8req", m["wall_s"] * 1e6 / 8,
                 f"{m['tokens_per_s']:.1f} tok/s "
                 f"ttft_p50={m['ttft_p50_ms']:.0f}ms"))
    return rows


def bench_sharded(shards: int, n_requests: int = 16):
    """Sharded-engine stream benchmark: per-shard tokens/s + latency
    percentiles (the PR-4 acceptance row)."""
    from repro.launch.serve import serve_stream

    m = serve_stream(ARCH, n_requests=n_requests, rate=100.0, max_seqs=4,
                     prompt_range=(16, 48), gen_range=(8, 24),
                     smoke=True, realtime=False, attn_backend="sharded",
                     shards=shards)
    rows = [(f"serve_sharded_s{shards}_stream",
             m["wall_s"] * 1e6 / n_requests,
             f"{m['tokens_per_s']:.1f} tok/s "
             f"ttft_p50/p99={m['ttft_p50_ms']:.0f}/"
             f"{m['ttft_p99_ms']:.0f}ms "
             f"lat_p50/p99={m['latency_p50_ms']:.0f}/"
             f"{m['latency_p99_ms']:.0f}ms")]
    for s, tps in enumerate(m["per_shard_tokens_per_s"]):
        rows.append((f"serve_sharded_s{shards}_shard{s}", 0.0,
                     f"{tps:.1f} tok/s "
                     f"{m['per_shard_requests'][s]} requests"))
    return rows


# ----------------------------------------------- prefix-cache JSON mode
def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


_STAT_KEYS = ("prefill_tokens", "prefix_hit_tokens",
              "prefix_prompt_tokens", "cow_copies", "swap_restores",
              "preemptions")


def _trace_prompts(rng, vocab, tr):
    prefix = rng.integers(0, vocab, tr["prefix_len"], dtype=np.int32)
    return [np.concatenate([prefix, rng.integers(
        0, vocab, 1 + int(rng.integers(tr["sfx"])),
        dtype=np.int32)]) for _ in range(tr["n"])]


def _serve_trace(cfg, params, prompts, tr, prefix_cache: bool):
    """Warm-then-measure on ONE engine: jit caches live per engine, so a
    throwaway pass over a content-disjoint trace of the same shape
    compiles every bucket the measured pass touches (full-context
    prefill, suffix prefill, decode, drain ops) without seeding the real
    trace's prefix into the tree.  Returns (outs, stat_deltas, wall,
    latencies, raw_stats) for the measured pass only."""
    from repro.serving.engine import Engine, EngineConfig

    max_len = _round_up(tr["prefix_len"] + tr["sfx"] + tr["gen"] + 1, 16)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=tr["max_seqs"], max_seq_len=max_len,
        num_pages=tr["num_pages"], prefix_cache=prefix_cache))
    warm = dict(tr, n=tr["max_seqs"] + 2)
    for p in _trace_prompts(np.random.default_rng(7), cfg.vocab_size,
                            warm):
        eng.submit(p, max_new_tokens=tr["gen"])
    eng.run(realtime=False)
    base = dict(eng.stats)
    t0 = eng._wall()
    reqs = [eng.submit(p, max_new_tokens=tr["gen"], arrival=t0)
            for p in prompts]
    w0 = time.perf_counter()
    eng.run(realtime=False)
    wall = time.perf_counter() - w0
    delta = {k: eng.stats[k] - base.get(k, 0) for k in _STAT_KEYS}
    lat = np.array([r.t_done - r.arrival for r in reqs])
    return [list(r.out) for r in reqs], delta, wall, lat, dict(eng.stats)


def _prefix_case(tr) -> dict:
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = get_smoke_config(ARCH)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = _trace_prompts(np.random.default_rng(42), cfg.vocab_size,
                             tr)

    paths, outs, stats = {}, {}, {}
    for pname, on in (("prefix_off", False), ("prefix_on", True)):
        out, st, wall, lat, raw = _serve_trace(cfg, params, prompts, tr,
                                               on)
        outs[pname], stats[pname] = out, st
        gen_tokens = sum(len(o) for o in out)
        paths[pname] = {
            "wall_us": wall * 1e6,
            "tokens_per_s": gen_tokens / max(wall, 1e-9),
            "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
            "prefill_tokens": st["prefill_tokens"],
            "pages_in_use_peak": raw["pages_in_use_peak"],
            "preemptions": st["preemptions"],
        }

    on_stats = stats["prefix_on"]
    exact = outs["prefix_on"] == outs["prefix_off"]
    hit_rate = (on_stats["prefix_hit_tokens"]
                / max(on_stats["prefix_prompt_tokens"], 1))
    # prefill tokens the cache actually elided, as a fraction of what
    # the off path prefilled (re-prefills after preemption included)
    saved = 1 - (on_stats["prefill_tokens"]
                 / max(stats["prefix_off"]["prefill_tokens"], 1))
    speedup = (paths["prefix_off"]["wall_us"]
               / max(paths["prefix_on"]["wall_us"], 1e-9))
    metrics = {
        "tokens_per_s": paths["prefix_on"]["tokens_per_s"],
        "latency_p50_ms": paths["prefix_on"]["latency_p50_ms"],
        "latency_p99_ms": paths["prefix_on"]["latency_p99_ms"],
        "prefix_hit_rate": hit_rate,
        "prefill_tokens_saved": saved,
        "pages_in_use_peak": paths["prefix_on"]["pages_in_use_peak"],
        "cow_copies": on_stats["cow_copies"],
        "swap_restores": on_stats["swap_restores"],
        "speedup": speedup,
    }
    if tr["kind"] == "preempt_swap":
        # undersized pool: the gated signals are exact replay through
        # swap/restore, not throughput (preemption timing is noisy)
        agree = exact and on_stats["swap_restores"] > 0
        for k in ("speedup", "prefix_hit_rate", "prefill_tokens_saved"):
            metrics[f"{k}_info"] = metrics.pop(k)
    else:
        agree = exact and metrics["prefill_tokens_saved"] >= 0.5 \
            and speedup > 1.0
    return {
        "name": f"serve_{tr['kind']}_P{tr['prefix_len']}",
        "trace": dict(tr),
        "exact": exact,
        "agree": agree,
        "metrics": metrics,
        "paths": paths,
    }


# ------------------------------------------------- open-loop JSON mode
def _open_loop_case(tr) -> dict:
    """One open-loop trace: the staged-API driver with dispatch-ahead vs
    the legacy closed loop on the identical workload, token-compared.
    Reports sustained tokens/s + TTFT/TPOT percentiles for the staged
    run (wall-derived, informational on CPU; check_regression holds
    them only to loose cross-machine floors/ceilings)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving import frontend as FE

    cfg = get_smoke_config(ARCH)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size,
                            tr["plen"] + 1 + int(rng.integers(tr["sfx"])),
                            dtype=np.int32) for _ in range(tr["n"])]
    max_len = _round_up(tr["plen"] + tr["sfx"] + tr["gen"] + 1, 16)

    def mk(da):
        return Engine(cfg, T.init_lm(jax.random.PRNGKey(0), cfg),
                      EngineConfig(max_seqs=tr["max_seqs"],
                                   max_seq_len=max_len,
                                   num_pages=tr["num_pages"],
                                   dispatch_ahead=da))

    legacy = mk(0)
    base = [legacy.submit(p, max_new_tokens=tr["gen"]) for p in prompts]
    w0 = time.perf_counter()
    legacy.run(realtime=False)
    legacy_wall = time.perf_counter() - w0

    da = tr["dispatch_ahead"]
    staged = mk(da)
    trace = [FE.TraceItem(prompt=p, max_new_tokens=tr["gen"],
                          arrival_step=i * tr["every"])
             for i, p in enumerate(prompts)]
    m = FE.time_open_loop(staged, trace)
    reqs = m.pop("_requests")
    exact = [list(r.out) for r in reqs] == [list(r.out) for r in base]
    # within-run gates: token exactness, the pipeline actually ran at
    # the configured depth, and undersized-pool traces really preempted
    agree = exact and m["dispatch_depth_peak"] >= da
    if tr["num_pages"]:
        agree = agree and m["preemptions"] > 0
    metrics = {
        "sustained_tokens_per_s": m["sustained_tokens_per_s"],
        "ttft_p50_ms": m["ttft_p50_ms"],
        "ttft_p99_ms": m["ttft_p99_ms"],
        "tpot_p50_ms": m["tpot_p50_ms"],
        "tpot_p99_ms": m["tpot_p99_ms"],
        "dispatch_depth_peak": m["dispatch_depth_peak"],
        "preemptions": m["preemptions"],
    }
    return {
        "name": f"serve_{tr['kind']}_da{da}",
        "trace": dict(tr),
        "exact": exact,
        "agree": agree,
        "metrics": metrics,
        "paths": {
            "legacy": {"wall_us": legacy_wall * 1e6},
            "staged": {"wall_us": m["wall_s"] * 1e6,
                       "decode_steps": m["decode_steps"],
                       "pipeline_drains": m["pipeline_drains"]},
        },
    }


def run_cases(traces):
    return [_prefix_case(tr) if tr["kind"].startswith("prefix")
            or tr["kind"] == "preempt_swap" else _open_loop_case(tr)
            for tr in traces]


def _report(cases):
    import jax

    return {
        "benchmark": "serve_throughput",
        "schema_version": SCHEMA_VERSION,
        "arch": ARCH,
        "dtype": "float32",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def _select_traces(args):
    prefix = SMOKE_TRACES if args.smoke else FULL_TRACES
    open_loop = OPEN_LOOP_SMOKE if args.smoke else OPEN_LOOP_FULL
    return {"prefix": prefix, "open-loop": open_loop,
            "all": prefix + open_loop}[args.traces]


def _json_main(args) -> int:
    cases = run_cases(_select_traces(args))
    report = _report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for c in cases:
        m = c["metrics"]
        if "sustained_tokens_per_s" in m:       # open-loop case
            print(f"{c['name']},{c['paths']['staged']['wall_us']:.1f},"
                  f"exact={c['exact']};"
                  f"depth_peak={m['dispatch_depth_peak']};"
                  f"tok_s={m['sustained_tokens_per_s']:.1f};"
                  f"ttft_p99={m['ttft_p99_ms']:.0f}ms")
            continue
        hit = m.get("prefix_hit_rate", m.get("prefix_hit_rate_info", 0))
        print(f"{c['name']},{c['paths']['prefix_on']['wall_us']:.1f},"
              f"exact={c['exact']};hit_rate={hit:.2f};"
              f"tok_s={m['tokens_per_s']:.1f}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"SERVE-TRACE DISAGREEMENT: {bad}", file=sys.stderr)
        return 1
    return 0


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help="benchmark the sharded engine with N page-pool "
                         "shards (0 = single-host rows)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--json", metavar="OUT", nargs="?", const="",
                    default=None,
                    help="run the prefix-cache traces and write the "
                         "machine-readable report here (the "
                         "BENCH_serve.json schema); bare --json prints "
                         "the CSV rows only")
    ap.add_argument("--smoke", action="store_true",
                    help="small traces only (the CI bench-smoke / "
                         "serve-smoke legs); implies the JSON mode")
    ap.add_argument("--traces", default="all",
                    choices=["all", "prefix", "open-loop"],
                    help="JSON mode trace family: prefix-cache traces, "
                         "staged-API open-loop traces (dispatch-ahead "
                         "vs the legacy closed loop), or both")
    args = ap.parse_args()
    if args.json is not None or args.smoke:
        args.json = args.json or None
        raise SystemExit(_json_main(args))
    if args.shards:
        # must happen before jax initializes (transitively via repro.*);
        # append so a pre-existing XLA_FLAGS keeps its flags, unless the
        # user already pinned a device count themselves
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.shards}").strip()
        rows = bench_sharded(args.shards, n_requests=args.requests)
    else:
        rows = bench()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    _main()
