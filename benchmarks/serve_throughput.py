"""Serving-engine throughput: continuous batching vs fixed-batch loop.

Rows: decode tokens/s and per-step prefill/decode latency for the paged
engine across batch sizes, against the legacy lockstep loop on the same
workload.  Derived column = tokens/s (engine rows additionally carry
ttft_p50 for the stream row).
"""
from __future__ import annotations

import time

import jax
import numpy as np

ARCH = "moba-340m"
PROMPT, GEN = 48, 24


def _engine_row(batch: int):
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig

    cfg = get_smoke_config(ARCH)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=batch, max_seq_len=PROMPT + GEN + 8,
        max_prefill_batch=min(batch, 4)))
    for i in range(batch):
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT, dtype=np.int32),
                   max_new_tokens=GEN)
    eng.run()   # includes compile; counters below reflect full wall time
    st = eng.stats
    dec_us = st["decode_s"] / max(st["decode_steps"], 1) * 1e6
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    pre_us = st["prefill_s"] / max(st["prefill_tokens"], 1) * 1e6
    return [(f"serve_engine_b{batch}_decode_step", dec_us,
             f"{tps:.1f} tok/s"),
            (f"serve_engine_b{batch}_prefill_per_tok", pre_us, "")]


def _fixed_row(batch: int):
    from repro.launch.serve import serve_fixed

    t0 = time.perf_counter()
    serve_fixed(ARCH, batch=batch, prompt_len=PROMPT, gen=GEN, smoke=True)
    wall = time.perf_counter() - t0
    tps = batch * GEN / wall
    return [(f"serve_fixed_b{batch}_total", wall * 1e6 / (batch * GEN),
             f"{tps:.1f} tok/s")]


def bench():
    rows = []
    for batch in (2, 4, 8):
        rows.extend(_engine_row(batch))
        rows.extend(_fixed_row(batch))
    # continuous-batching scenario the fixed loop cannot express:
    # staggered Poisson arrivals with mixed prompt/gen lengths
    from repro.launch.serve import serve_stream
    m = serve_stream(ARCH, n_requests=8, rate=100.0, max_seqs=4,
                     prompt_range=(16, 48), gen_range=(8, 24),
                     smoke=True, realtime=False)
    rows.append(("serve_stream_8req", m["wall_s"] * 1e6 / 8,
                 f"{m['tokens_per_s']:.1f} tok/s "
                 f"ttft_p50={m['ttft_p50_ms']:.0f}ms"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")
