"""Serving-engine throughput: continuous batching vs fixed-batch loop.

Rows: decode tokens/s and per-step prefill/decode latency for the paged
engine across batch sizes, against the legacy lockstep loop on the same
workload.  Derived column = tokens/s (engine rows additionally carry
ttft_p50 for the stream row).

``--shards N`` instead benchmarks the sharded engine (one shard_map
decode across N page-pool shards) on a Poisson stream and reports
per-shard tokens/s plus p50/p99 TTFT and end-to-end latency.  Devices
are simulated on the host platform when fewer than N are visible, so
the flag works on a laptop (throughput numbers are then about dispatch
overheads, not real parallel speedup).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

ARCH = "moba-340m"
PROMPT, GEN = 48, 24


def _engine_row(batch: int):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig

    cfg = get_smoke_config(ARCH)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=batch, max_seq_len=PROMPT + GEN + 8,
        max_prefill_batch=min(batch, 4)))
    for i in range(batch):
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT, dtype=np.int32),
                   max_new_tokens=GEN)
    eng.run()   # includes compile; counters below reflect full wall time
    st = eng.stats
    dec_us = st["decode_s"] / max(st["decode_steps"], 1) * 1e6
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    pre_us = st["prefill_s"] / max(st["prefill_tokens"], 1) * 1e6
    return [(f"serve_engine_b{batch}_decode_step", dec_us,
             f"{tps:.1f} tok/s"),
            (f"serve_engine_b{batch}_prefill_per_tok", pre_us, "")]


def _fixed_row(batch: int):
    from repro.launch.serve import serve_fixed

    t0 = time.perf_counter()
    serve_fixed(ARCH, batch=batch, prompt_len=PROMPT, gen=GEN, smoke=True)
    wall = time.perf_counter() - t0
    tps = batch * GEN / wall
    return [(f"serve_fixed_b{batch}_total", wall * 1e6 / (batch * GEN),
             f"{tps:.1f} tok/s")]


def bench():
    rows = []
    for batch in (2, 4, 8):
        rows.extend(_engine_row(batch))
        rows.extend(_fixed_row(batch))
    # continuous-batching scenario the fixed loop cannot express:
    # staggered Poisson arrivals with mixed prompt/gen lengths
    from repro.launch.serve import serve_stream
    m = serve_stream(ARCH, n_requests=8, rate=100.0, max_seqs=4,
                     prompt_range=(16, 48), gen_range=(8, 24),
                     smoke=True, realtime=False)
    rows.append(("serve_stream_8req", m["wall_s"] * 1e6 / 8,
                 f"{m['tokens_per_s']:.1f} tok/s "
                 f"ttft_p50={m['ttft_p50_ms']:.0f}ms"))
    return rows


def bench_sharded(shards: int, n_requests: int = 16):
    """Sharded-engine stream benchmark: per-shard tokens/s + latency
    percentiles (the PR-4 acceptance row)."""
    from repro.launch.serve import serve_stream

    m = serve_stream(ARCH, n_requests=n_requests, rate=100.0, max_seqs=4,
                     prompt_range=(16, 48), gen_range=(8, 24),
                     smoke=True, realtime=False, attn_backend="sharded",
                     shards=shards)
    rows = [(f"serve_sharded_s{shards}_stream",
             m["wall_s"] * 1e6 / n_requests,
             f"{m['tokens_per_s']:.1f} tok/s "
             f"ttft_p50/p99={m['ttft_p50_ms']:.0f}/"
             f"{m['ttft_p99_ms']:.0f}ms "
             f"lat_p50/p99={m['latency_p50_ms']:.0f}/"
             f"{m['latency_p99_ms']:.0f}ms")]
    for s, tps in enumerate(m["per_shard_tokens_per_s"]):
        rows.append((f"serve_sharded_s{shards}_shard{s}", 0.0,
                     f"{tps:.1f} tok/s "
                     f"{m['per_shard_requests'][s]} requests"))
    return rows


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help="benchmark the sharded engine with N page-pool "
                         "shards (0 = single-host rows)")
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args()
    if args.shards:
        # must happen before jax initializes (transitively via repro.*);
        # append so a pre-existing XLA_FLAGS keeps its flags, unless the
        # user already pinned a device count themselves
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.shards}").strip()
        rows = bench_sharded(args.shards, n_requests=args.requests)
    else:
        rows = bench()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    _main()
