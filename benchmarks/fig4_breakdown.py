"""Paper Fig. 4 — forward-pass stage breakdown.

Times each FlashMoBA pipeline stage separately (centroids, topk, layout,
gather, attention, merge) and the original-MoBA stages (scores+topk on a
materialized matrix, reindex, attention) on CPU.  The paper's claim: the
original's routing overheads dominate; FlashMoBA makes them negligible.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import moba as M, routing
from repro.kernels import ref as kref


def run(n: int = 4096, d: int = 64, bs: int = 64, k: int = 4,
        reps: int = 3):
    cfg = MoBAConfig(block_size=bs, top_k=k)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (1, 2, n, d), jnp.float32)
    kk = jax.random.normal(keys[1], (1, 2, n, d), jnp.float32)
    v = jax.random.normal(keys[2], (1, 2, n, d), jnp.float32)
    nb = n // bs

    def timeit(f, *a):
        g = jax.jit(f)
        jax.block_until_ready(g(*a))
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(g(*a))
        return (time.time() - t0) / reps * 1e3

    stages = {}
    stages["1_centroids"] = timeit(
        lambda kk: routing.block_centroids(kk, bs), kk)
    cents = routing.block_centroids(kk, bs)
    stages["2_topk_tiled"] = timeit(
        lambda q, kk: M.moba_selection(q, kk, cfg), q, kk)
    sel = M.moba_selection(q, kk, cfg)
    stages["3_layout+gather+attn+merge"] = timeit(
        lambda q, kk, v: kref.moba_sparse_xla(q, kk, v, cfg, tile=64),
        q, kk, v)

    # original-style: N×N masked attention incl. full mask materialization
    stages["orig_full_pipeline"] = timeit(
        lambda q, kk, v: M.moba_attention_reference(q, kk, v, cfg),
        q, kk, v)
    total_flash = sum(v for s, v in stages.items() if not
                      s.startswith("orig"))
    print(f"# fig4 breakdown  N={n} B={bs} k={k} (CPU ms)")
    for s, v in stages.items():
        print(f"  {s:<28} {v:8.1f} ms")
    print(f"  {'flash_total':<28} {total_flash:8.1f} ms")
    return stages


def bench():
    t0 = time.time()
    stages = run(n=2048)
    us = (time.time() - t0) * 1e6
    flash = sum(v for s, v in stages.items() if not s.startswith("orig"))
    return [("fig4_breakdown", us,
             f"flash={flash:.0f}ms;orig={stages['orig_full_pipeline']:.0f}ms")]


if __name__ == "__main__":
    run()
