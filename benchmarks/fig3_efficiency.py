"""Paper Fig. 3 — latency & memory vs sequence length.

CPU cannot reproduce H100 wall-clock, so this benchmark reports what CAN
be measured honestly:
  (a) analytic FLOPs + HBM bytes for dense attention vs original-MoBA
      (materialized N×nb score matrix + global reindex) vs FlashMoBA
      (tiled topk + gather-and-densify) — the paper's asymptotic story;
  (b) measured CPU wall-time of the three *algorithm structures* in
      jitted XLA at small N, confirming the crossover direction.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import ref as kref


def analytic(n: int, d: int = 64, bs: int = 128, k: int = 8):
    """Per-head forward FLOPs and bytes (bf16)."""
    nb = n // bs
    dense_flops = 2 * n * n * d * 2            # QK^T + PV
    moba_flops = 2 * n * nb * d + 2 * n * k * bs * d * 2
    # original MoBA materializes (N, nb) scores + full reindex of q/k/v
    orig_bytes = 2 * (n * nb + 3 * n * d + 2 * n * k * bs * d / 128)
    flash_bytes = 2 * (3 * n * d + n * k * d + 2 * nb * bs * d)
    dense_bytes = 2 * (3 * n * d + n * d)
    return dense_flops, moba_flops, orig_bytes, flash_bytes, dense_bytes


def measured(n: int, d: int = 64, bs: int = 64, k: int = 4, reps: int = 3):
    """CPU wall-time of the three pipelines (B=1, H=2)."""
    cfg = MoBAConfig(block_size=bs, top_k=k)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (1, 2, n, d), jnp.float32)
    kk = jax.random.normal(keys[1], (1, 2, n, d), jnp.float32)
    v = jax.random.normal(keys[2], (1, 2, n, d), jnp.float32)

    from repro.core.attention import dense_attention

    def orig_moba(q, kk, v):
        # original-style: full mask materialization (the N^2 cost the
        # paper's Fig. 4 shows dominating)
        return M.moba_attention_reference(q, kk, v, cfg)

    def flash_moba(q, kk, v):
        return kref.moba_sparse_xla(q, kk, v, cfg, tile=64)

    out = {}
    for name, fn in [("dense", dense_attention), ("moba_orig", orig_moba),
                     ("flashmoba_xla", flash_moba)]:
        f = jax.jit(fn)
        f(q, kk, v).block_until_ready()
        t0 = time.time()
        for _ in range(reps):
            f(q, kk, v).block_until_ready()
        out[name] = (time.time() - t0) / reps * 1e3
    return out


def run():
    print("# analytic per-head fwd FLOPs (d=64, B=128, k=8)")
    print(f"{'N':>8}{'dense':>12}{'moba':>12}{'ratio':>8}")
    for n in (8192, 32768, 131072, 524288):
        df, mf, ob, fb, db = analytic(n)
        print(f"{n:>8}{df:>12.3e}{mf:>12.3e}{df/mf:>8.1f}")
    print("\n# measured CPU ms (algorithm structure, small N)")
    rows = []
    print(f"{'N':>8}{'dense':>10}{'orig':>10}{'flash':>10}")
    for n in (1024, 2048, 4096):
        r = measured(n)
        rows.append((n, r))
        print(f"{n:>8}{r['dense']:>10.1f}{r['moba_orig']:>10.1f}"
              f"{r['flashmoba_xla']:>10.1f}")
    return rows


def bench():
    t0 = time.time()
    rows = run()
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    n, r = rows[-1]
    speedup = r["moba_orig"] / r["flashmoba_xla"]
    return [("fig3_efficiency", us,
             f"N={n};flash_vs_orig={speedup:.1f}x")]


if __name__ == "__main__":
    run()
