"""Paper Fig. 3 — the small-block efficiency crossover, on our kernels.

The paper's headline claim is that FlashMoBA makes theoretically-better
*small* block sizes practical.  This benchmark drives the real Pallas
pipeline (``ops.flash_moba``: centroids → grouped flash_topk → varlen
layout → kb-tiled fwd) across block sizes {32, 64, 128, 256} × sequence
lengths, against jitted dense attention and the O(N²) oracle:

  measured   wall-time per path (informational in interpret mode — CPU
             wall-clock is not TPU-meaningful), oracle agreement, and
             the analytic FLOPs/HBM-bytes attached per case;
  analytic   the asymptotic story at paper-scale N: per-head FLOPs and
             bytes for dense vs the FlashMoBA pipeline, the dense/moba
             ratios, and per-block-size ``crossover_n`` — the smallest
             N in the sweep where MoBA's total FLOPs drop below dense.
             Small blocks pay more routing FLOPs (nb = N/bs grows) but
             touch k·bs ≪ N keys; the ratio approaches 2·bs·… only at
             large N, which is exactly the regime the paper plots.

``--json out.json`` writes the same stable schema family as
``decode_micro`` / ``kernels_micro`` (consumed by the CI bench-smoke
leg and the committed ``BENCH_fig3.json`` snapshot); the process exits
non-zero when the kernel pipeline disagrees with the oracle.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.core.attention import dense_attention
from repro.kernels import ops
from repro.kernels.runtime import resolve_interpret

SCHEMA_VERSION = 1
AGREE_TOL = 5e-3
ITERS = 3
Q_TILE = 128
CENT_TILE = 128
D = 64
H, HKV = 2, 1                       # G = 2 exercises the grouped grids

BLOCK_SIZES = (32, 64, 128, 256)
MEASURED_N = (512, 1024, 2048)
SMOKE_N = (512,)
SMOKE_BS = (32, 64)
ANALYTIC_N = (8192, 32768, 131072, 524288)


def _top_k(n: int, bs: int) -> int:
    """~1/8 key coverage, at least two blocks (paper's sparsity regime)."""
    return max(2, n // (8 * bs))


def _flops(n, bs, k, d=D):
    """Per-head forward FLOPs: dense QKᵀ+PV vs MoBA routing + gathered
    attention over the N·k routed pairs."""
    nb = -(-n // bs)
    dense = 2 * 2 * n * n * d
    moba = 2 * n * nb * d + 2 * 2 * n * k * bs * d
    return dense, moba


def _bytes(n, bs, k, d=D, isz=4):
    """Per-head HBM bytes: streaming dense (q, k, v in, o out) vs the
    FlashMoBA pipeline (centroids + topk centroid stream + sorted-Q
    gather + per-tile K/V stream + fp32 partials) — the same model as
    ``kernels_micro`` at H = Hkv = 1."""
    nb = -(-n // bs)
    nct = -(-nb // CENT_TILE)
    tile = min(Q_TILE, n)
    L = n * k + nb * tile
    dense = (3 * n * d + n * d) * isz
    moba = ((n + nb) * d * isz                      # centroid build
            + n * d * isz                           # topk Q read
            + (n // tile) * nct * CENT_TILE * d * isz   # centroid stream
            + n * k * 4                             # selection write
            + L * (d * isz + 4)                     # sorted Q + positions
            + (L // tile) * bs * d * isz * 2        # per-tile K/V stream
            + L * (d + 2) * 4)                      # (o, m, l) partials
    return dense, moba


def run_measured(ns, block_sizes):
    cases = []
    for n in ns:
        keys = jax.random.split(jax.random.PRNGKey(n), 3)
        q = jax.random.normal(keys[0], (1, H, n, D), jnp.float32) * 0.5
        kk = jax.random.normal(keys[1], (1, HKV, n, D), jnp.float32) * 0.5
        v = jax.random.normal(keys[2], (1, HKV, n, D), jnp.float32)
        kv_dense = (jnp.repeat(kk, H // HKV, axis=1),
                    jnp.repeat(v, H // HKV, axis=1))
        for bs in block_sizes:
            k = _top_k(n, bs)
            cfg = MoBAConfig(block_size=bs, top_k=k)
            oref = M.moba_attention_reference(q, kk, v, cfg)
            dense_fl, moba_fl = _flops(n, bs, k)
            dense_by, moba_by = _bytes(n, bs, k)

            paths = {}
            fn_d = jax.jit(lambda q, kf, vf: dense_attention(q, kf, vf,
                                                             causal=True))
            fn_f = jax.jit(lambda q, kk, v, c=cfg:
                           ops.flash_moba(q, kk, v, c, q_tile=Q_TILE,
                                          grid="grouped"))
            for pname, fn, args, flops, hbm in (
                    ("dense_xla", fn_d, (q, *kv_dense), H * dense_fl,
                     H * dense_by),
                    ("flash_moba", fn_f, (q, kk, v), H * moba_fl,
                     H * moba_by)):
                o = fn(*args).block_until_ready()
                t0 = time.perf_counter()
                for _ in range(ITERS):
                    fn(*args).block_until_ready()
                wall_us = (time.perf_counter() - t0) / ITERS * 1e6
                paths[pname] = {"wall_us": wall_us, "flops": flops,
                                "hbm_bytes": hbm}
                if pname == "flash_moba":
                    paths[pname]["max_abs_diff_vs_reference"] = float(
                        jnp.abs(o - oref).max())
            cases.append({
                "name": f"fig3_N{n}_B{bs}",
                "shape": {"batch": 1, "heads": H, "kv_heads": HKV,
                          "head_dim": D, "seq_len": n, "block_size": bs,
                          "top_k": k, "dtype": "float32"},
                "flops_ratio": dense_fl / moba_fl,
                "bytes_ratio": dense_by / moba_by,
                "agree_tol": AGREE_TOL,
                "agree": (paths["flash_moba"]["max_abs_diff_vs_reference"]
                          <= AGREE_TOL),
                "paths": paths,
            })
    return cases


def run_analytic(block_sizes):
    rows = []
    for bs in block_sizes:
        for n in ANALYTIC_N:
            k = _top_k(n, bs)
            dense_fl, moba_fl = _flops(n, bs, k)
            dense_by, moba_by = _bytes(n, bs, k)
            rows.append({"n": n, "block_size": bs, "top_k": k,
                         "dense_flops": dense_fl, "moba_flops": moba_fl,
                         "flops_ratio": dense_fl / moba_fl,
                         "dense_bytes": dense_by, "moba_bytes": moba_by,
                         "bytes_ratio": dense_by / moba_by})
    return rows


def crossover(block_sizes, ns):
    """Per block size: the smallest N where MoBA's total forward FLOPs
    drop below dense (the Fig. 3 crossover), over the full sweep."""
    out = {}
    for bs in block_sizes:
        xn = None
        for n in sorted(set(ns) | set(ANALYTIC_N)):
            dense_fl, moba_fl = _flops(n, bs, _top_k(n, bs))
            if moba_fl < dense_fl:
                xn = n
                break
        big = ANALYTIC_N[-1]
        dense_fl, moba_fl = _flops(big, bs, _top_k(big, bs))
        out[f"bs{bs}"] = {"crossover_n": xn,
                          "flops_ratio_at_max_n": dense_fl / moba_fl}
    return out


def _report(cases, analytic_rows, xover):
    return {
        "benchmark": "fig3_efficiency",
        "schema_version": SCHEMA_VERSION,
        "dtype": "float32",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "interpret": resolve_interpret(None),
        "agree_tol": AGREE_TOL,
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
        "analytic": analytic_rows,
        "crossover": xover,
    }


def run():
    """Human-readable sweep (kept for the run.py hook and direct use)."""
    cases = run_measured(MEASURED_N, BLOCK_SIZES)
    print(f"{'case':>18}{'dense us':>12}{'flash us':>12}"
          f"{'flops x':>9}{'bytes x':>9}{'maxerr':>10}")
    for c in cases:
        p = c["paths"]
        print(f"{c['name']:>18}{p['dense_xla']['wall_us']:>12.0f}"
              f"{p['flash_moba']['wall_us']:>12.0f}"
              f"{c['flops_ratio']:>9.2f}{c['bytes_ratio']:>9.2f}"
              f"{p['flash_moba']['max_abs_diff_vs_reference']:>10.1e}")
    print("\n# analytic crossover (per-head fwd FLOPs, d=64)")
    for key, x in crossover(BLOCK_SIZES, MEASURED_N).items():
        print(f"{key}: crossover_n={x['crossover_n']} "
              f"ratio@{ANALYTIC_N[-1]}={x['flops_ratio_at_max_n']:.1f}x")
    return cases


def bench():
    """run.py hook: flatten the measured cases into its CSV rows."""
    rows = []
    for c in run_measured(MEASURED_N[:1], SMOKE_BS):
        p = c["paths"]["flash_moba"]
        rows.append((c["name"], p["wall_us"],
                     f"maxerr={p['max_abs_diff_vs_reference']:.1e};"
                     f"flops_ratio={c['flops_ratio']:.2f}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here "
                         "(the BENCH_fig3.json schema)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes only (the CI bench-smoke leg)")
    args = ap.parse_args(argv)
    ns = SMOKE_N if args.smoke else MEASURED_N
    bss = SMOKE_BS if args.smoke else BLOCK_SIZES
    cases = run_measured(ns, bss)
    report = _report(cases, run_analytic(bss), crossover(bss, ns))
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for c in cases:
        p = c["paths"]
        print(f"{c['name']},{p['flash_moba']['wall_us']:.1f},"
              f"maxerr={p['flash_moba']['max_abs_diff_vs_reference']:.1e};"
              f"flops_ratio={c['flops_ratio']:.2f};"
              f"bytes_ratio={c['bytes_ratio']:.2f}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"ORACLE DISAGREEMENT beyond {AGREE_TOL}: {bad}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
