"""Paper Tables 3/4 — RULER S-NIAH long-context retrieval (router-level).

The paper's mechanism: retrieval works iff the MoBA router ranks the
needle's block in the top-k.  We measure exactly that — router retrieval
accuracy on planted needle batches across context lengths and block sizes,
with and without key convolution (kconv raises Δμ_eff via clustering, so
its effect is visible at the router level without 100B-token training).
Keys here are embeddings of a planted-signal process (App. A model).

``main`` (the CLI) runs the **adaptive-routing harness** on top of the
same planted-signal generator: a heterogeneous multi-head workload where
half the query heads carry a strong clustered needle signal and half are
diffuse noise heads.  It calibrates per-head SNR through the real
capture hook (`core.adaptive`), inverts the App. A.4 bound into per-head
budgets, and measures needle accuracy + selected-page HBM traffic for
static vs adaptive routing.  ``--json`` emits the ``BENCH_adaptive.json``
schema gated by ``check_regression.py``; ``--route-policy snr:pfail=P``
narrows the sweep to one failure budget (the CI adaptive leg).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import adaptive as AD
from repro.core import moba as M
from repro.core.key_conv import apply_key_conv, init_key_conv


def _planted_qkv(key, n, d, delta=0.5, m_cluster=4, mu_c=0.35):
    """Query + keys with an m-token clustered needle at a random block."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (d,))
    q = q / jnp.linalg.norm(q)
    keys = jax.random.normal(k2, (n, d))
    keys = keys / jnp.linalg.norm(keys, axis=-1, keepdims=True)
    pos = int(jax.random.randint(k3, (), 0, n - m_cluster - 1))
    for i in range(m_cluster):
        mu = delta if i == 0 else mu_c
        vec = keys[pos + i]
        orth = vec - (vec @ q) * q
        orth = orth / jnp.linalg.norm(orth)
        keys = keys.at[pos + i].set(mu * q + float(np.sqrt(1 - mu * mu))
                                    * orth)
    return q, keys, pos


def run(lengths=(1024, 2048, 4096, 8192), trials: int = 60, d: int = 64,
        seed: int = 0):
    print("# router retrieval accuracy (needle block in top-k)")
    cfgs = [("B256,k2", 256, 2, 0), ("B128,k4", 128, 4, 0),
            ("B64,k8", 64, 8, 0), ("B64,k8+kconv3", 64, 8, 3)]
    header = f"{'config':<16}" + "".join(f"{n:>8}" for n in lengths)
    print(header)
    out = {}
    for name, bs, k, conv_w in cfgs:
        accs = []
        for n in lengths:
            hit = 0
            key = jax.random.PRNGKey(seed)
            conv = (init_key_conv(jax.random.PRNGKey(1), conv_w, 1, d) * 8
                    if conv_w else None)
            for t in range(trials):
                key, k2 = jax.random.split(key)
                q, keys, pos = _planted_qkv(k2, n, d)
                kk = keys[None, None]
                if conv is not None:
                    kk = apply_key_conv(conv, kk)
                cfg = MoBAConfig(block_size=bs, top_k=k)
                sel = M.moba_selection(q[None, None, None], kk, cfg,
                                       q_positions=jnp.array([n - 1]))
                hit += int((sel[0, 0, 0] == pos // bs).any())
            accs.append(hit / trials)
        out[name] = accs
        print(f"{name:<16}" + "".join(f"{a:>8.2f}" for a in accs))
    return out


def bench():
    t0 = time.time()
    out = run(lengths=(1024, 4096), trials=30)
    us = (time.time() - t0) * 1e6 / len(out)
    small_b = out["B64,k8"][-1]
    big_b = out["B256,k2"][-1]
    return [("table34_niah_router", us,
             f"B64@4k={small_b:.2f};B256@4k={big_b:.2f}")]


# ------------------------------------------------- adaptive harness
# One planted-signal config (paper App. A constants): d=64, B=32 blocks
# of a 2048-token context, k_max=8.  Strong heads (g == 0) carry an
# m=8-token needle cluster at mu_c=0.75 toward the head's query
# direction — Δμ_eff ≈ m·mu_c/B·sqrt(B·d) ≈ 8.5σ, far above the
# pfail=0.01 budget for one score slot — while weak heads (g == 1) see
# pure noise (max-of-63 ≈ 2.9σ, below every bound) and keep k_max.
SCHEMA_VERSION = 1
AD_D = 64
AD_BS = 32
AD_NB = 64                      # context = AD_NB * AD_BS = 2048 tokens
AD_KMAX = 8
AD_HKV = 2
AD_GROUPS = 2                   # H = 4 query heads; g == 0 strong
AD_BATCH = 4                    # sequences per decode step
AD_M_CLUSTER = 8
AD_MU_C = 0.75
AD_CALIB_STEPS = 2              # identical in smoke and full runs
AD_EVAL_STEPS = 6
AD_SMOKE_EVAL_STEPS = 2
# fp32 K + V page reads per selected block
AD_PAGE_BYTES = AD_BS * AD_D * 2 * 4


def _adaptive_batch(rng, n):
    """One heterogeneous planted batch for the adaptive harness.

    Returns q (B, H, 1, d), keys (B, Hkv, n, d), needle block (B, Hkv).
    Keys are unit rows; per (seq, kv head) an AD_M_CLUSTER-token needle
    is planted at a random non-final block along a direction u.  Strong
    query heads (g == 0) ask u; weak heads ask an independent random
    direction.
    """
    d, bs = AD_D, AD_BS
    nb = n // bs
    keys = rng.standard_normal((AD_BATCH, AD_HKV, n, d))
    keys /= np.linalg.norm(keys, axis=-1, keepdims=True)
    u = rng.standard_normal((AD_BATCH, AD_HKV, d))
    u /= np.linalg.norm(u, axis=-1, keepdims=True)
    pos = rng.integers(0, nb - 1, (AD_BATCH, AD_HKV))
    for b in range(AD_BATCH):
        for h in range(AD_HKV):
            t0 = int(pos[b, h]) * bs
            for i in range(AD_M_CLUSTER):
                v = keys[b, h, t0 + i]
                v = v - (v @ u[b, h]) * u[b, h]
                v /= np.linalg.norm(v)
                keys[b, h, t0 + i] = (AD_MU_C * u[b, h]
                                      + np.sqrt(1 - AD_MU_C ** 2) * v)
    q = rng.standard_normal((AD_BATCH, AD_HKV, AD_GROUPS, d))
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    q[:, :, 0] = u                             # strong retrieval heads
    q = q.reshape(AD_BATCH, AD_HKV * AD_GROUPS, 1, d)
    return (jnp.asarray(q, jnp.float32),
            jnp.asarray(keys, jnp.float32), pos)


def _calibrate_heads(cfg, n, pfail, seed):
    """Measured (Hkv, G) SNR + per-head budgets via the real capture
    hook — the same estimator `calibrate_profile` runs inside a model."""
    rng = np.random.default_rng(seed)
    qpos = jnp.array([n - 1])
    snrs = []
    for _ in range(AD_CALIB_STEPS):
        q, keys, _ = _adaptive_batch(rng, n)
        with AD.capture_routing_scores() as caps:
            M.moba_selection(q, keys, cfg, q_positions=qpos)
        scores, qp = caps[0]
        snrs.append(AD.estimate_head_snr(np.asarray(scores),
                                         np.asarray(qp), AD_BS))
    snr_hat = np.mean(snrs, axis=0)
    head_top_k = AD.choose_top_k(snr_hat, n // AD_BS, cfg.top_k, pfail)
    return snr_hat, head_top_k


def run_adaptive_case(pfail: float, smoke: bool = False) -> dict:
    """Calibrate, then measure static vs adaptive routing on fresh
    planted batches: strong-head needle accuracy + selected-page HBM
    traffic per decode step (analytic fp32 K/V page reads)."""
    n = AD_NB * AD_BS
    nb = AD_NB
    cfg = MoBAConfig(block_size=AD_BS, top_k=AD_KMAX)
    snr_hat, head_top_k = _calibrate_heads(cfg, n, pfail, seed=0)
    htk = jnp.asarray(head_top_k, jnp.int32)

    steps = AD_SMOKE_EVAL_STEPS if smoke else AD_EVAL_STEPS
    rng = np.random.default_rng(1000)
    qpos = jnp.array([n - 1])
    hits = {"static": 0, "adaptive": 0}
    pages = {"static": 0, "adaptive": 0}
    total = 0
    for _ in range(steps):
        q, keys, pos = _adaptive_batch(rng, n)
        sels = {
            "static": np.asarray(
                M.moba_selection(q, keys, cfg, q_positions=qpos)),
            "adaptive": np.asarray(
                M.moba_selection(q, keys, cfg, q_positions=qpos,
                                 head_top_k=htk)),
        }
        for path, sel in sels.items():
            pages[path] += int((sel < nb).sum())
            for hk in range(AD_HKV):        # strong heads: g == 0
                h = hk * AD_GROUPS
                hit = (sel[:, h, 0, :] == pos[:, hk, None]).any(-1)
                hits[path] += int(hit.sum())
        total += AD_BATCH * AD_HKV
    acc = {p: hits[p] / total for p in hits}
    page_step = {p: pages[p] / steps for p in pages}
    bytes_step = {p: page_step[p] * AD_PAGE_BYTES for p in pages}
    ratio = bytes_step["adaptive"] / bytes_step["static"]
    agree = (acc["adaptive"] >= acc["static"] - 0.01 - 1e-9
             and ratio <= 0.80)
    return {
        "name": f"niah_adaptive_pf{pfail}_b{AD_BS}_nb{AD_NB}",
        "pfail": pfail,
        "block_size": AD_BS, "num_blocks": AD_NB, "d": AD_D,
        "k_max": AD_KMAX, "heads": AD_HKV * AD_GROUPS,
        "eval_steps": steps, "needle_trials": total,
        "snr_hat": np.round(snr_hat, 3).tolist(),
        "head_top_k": head_top_k.tolist(),
        "paths": {
            p: {"hbm_bytes": bytes_step[p],
                "pages_selected": page_step[p],
                "accuracy": acc[p]} for p in ("static", "adaptive")
        },
        "metrics": {
            "accuracy_static": acc["static"],
            "accuracy_adaptive": acc["adaptive"],
            "bytes_ratio": ratio,
        },
        "agree": agree,
    }


def _adaptive_report(cases):
    return {
        "benchmark": "table34_adaptive",
        "schema_version": SCHEMA_VERSION,
        "dtype": "float32",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--route-policy", default=None,
                    help='"snr:pfail=P" narrows the sweep to one '
                         "failure budget (default: 0.01 and 0.05)")
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here "
                         "(the BENCH_adaptive.json schema)")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer eval steps (the CI adaptive leg); "
                         "calibration and budgets are identical")
    ap.add_argument("--router-table", action="store_true",
                    help="print the original Tables 3/4 router-accuracy "
                         "sweep instead of the adaptive harness")
    args = ap.parse_args(argv)
    if args.router_table:
        run()
        return 0
    pfails = (0.01, 0.05)
    if args.route_policy:
        mode, arg = AD.parse_route_policy(args.route_policy)
        if mode != "snr":
            ap.error(f"the adaptive harness needs an snr policy, got "
                     f"{args.route_policy!r}")
        pfails = (arg,)
    cases = [run_adaptive_case(pf, smoke=args.smoke) for pf in pfails]
    report = _adaptive_report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    print(f"{'case':<34}{'acc_s':>7}{'acc_a':>7}{'bytes_x':>9}"
          f"{'budgets':>14}")
    for c in cases:
        m = c["metrics"]
        flat = [k for row in c["head_top_k"] for k in row]
        print(f"{c['name']:<34}{m['accuracy_static']:>7.2f}"
              f"{m['accuracy_adaptive']:>7.2f}"
              f"{m['bytes_ratio']:>9.3f}{str(flat):>14}")
    if not report["agree"]:
        print("FAIL: adaptive routing lost accuracy or missed the "
              "byte-reduction target", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
