"""Paper Tables 3/4 — RULER S-NIAH long-context retrieval (router-level).

The paper's mechanism: retrieval works iff the MoBA router ranks the
needle's block in the top-k.  We measure exactly that — router retrieval
accuracy on planted needle batches across context lengths and block sizes,
with and without key convolution (kconv raises Δμ_eff via clustering, so
its effect is visible at the router level without 100B-token training).
Keys here are embeddings of a planted-signal process (App. A model).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.core.key_conv import apply_key_conv, init_key_conv


def _planted_qkv(key, n, d, delta=0.5, m_cluster=4, mu_c=0.35):
    """Query + keys with an m-token clustered needle at a random block."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (d,))
    q = q / jnp.linalg.norm(q)
    keys = jax.random.normal(k2, (n, d))
    keys = keys / jnp.linalg.norm(keys, axis=-1, keepdims=True)
    pos = int(jax.random.randint(k3, (), 0, n - m_cluster - 1))
    for i in range(m_cluster):
        mu = delta if i == 0 else mu_c
        vec = keys[pos + i]
        orth = vec - (vec @ q) * q
        orth = orth / jnp.linalg.norm(orth)
        keys = keys.at[pos + i].set(mu * q + float(np.sqrt(1 - mu * mu))
                                    * orth)
    return q, keys, pos


def run(lengths=(1024, 2048, 4096, 8192), trials: int = 60, d: int = 64,
        seed: int = 0):
    print("# router retrieval accuracy (needle block in top-k)")
    cfgs = [("B256,k2", 256, 2, 0), ("B128,k4", 128, 4, 0),
            ("B64,k8", 64, 8, 0), ("B64,k8+kconv3", 64, 8, 3)]
    header = f"{'config':<16}" + "".join(f"{n:>8}" for n in lengths)
    print(header)
    out = {}
    for name, bs, k, conv_w in cfgs:
        accs = []
        for n in lengths:
            hit = 0
            key = jax.random.PRNGKey(seed)
            conv = (init_key_conv(jax.random.PRNGKey(1), conv_w, 1, d) * 8
                    if conv_w else None)
            for t in range(trials):
                key, k2 = jax.random.split(key)
                q, keys, pos = _planted_qkv(k2, n, d)
                kk = keys[None, None]
                if conv is not None:
                    kk = apply_key_conv(conv, kk)
                cfg = MoBAConfig(block_size=bs, top_k=k)
                sel = M.moba_selection(q[None, None, None], kk, cfg,
                                       q_positions=jnp.array([n - 1]))
                hit += int((sel[0, 0, 0] == pos // bs).any())
            accs.append(hit / trials)
        out[name] = accs
        print(f"{name:<16}" + "".join(f"{a:>8.2f}" for a in accs))
    return out


def bench():
    t0 = time.time()
    out = run(lengths=(1024, 4096), trials=30)
    us = (time.time() - t0) * 1e6 / len(out)
    small_b = out["B64,k8"][-1]
    big_b = out["B256,k2"][-1]
    return [("table34_niah_router", us,
             f"B64@4k={small_b:.2f};B256@4k={big_b:.2f}")]


if __name__ == "__main__":
    run()
