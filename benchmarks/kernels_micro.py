"""Pallas kernel microbenchmarks (interpret-mode correctness + op counts).

Wall-time in interpret mode is not meaningful for TPU perf; what this
records is that each kernel runs and matches its oracle at benchmark
shapes, plus the analytic FLOPs each kernel performs (the §Roofline
compute-side inputs for the kernel path).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import ops


def bench():
    rows = []
    for (n, bs, k, d) in [(512, 64, 2, 64), (1024, 128, 2, 64)]:
        cfg = MoBAConfig(block_size=bs, top_k=k)
        keys = jax.random.split(jax.random.PRNGKey(n), 3)
        q = jax.random.normal(keys[0], (1, 2, n, d), jnp.float32) * 0.5
        kk = jax.random.normal(keys[1], (1, 1, n, d), jnp.float32) * 0.5
        v = jax.random.normal(keys[2], (1, 1, n, d), jnp.float32)
        t0 = time.time()
        o = ops.flash_moba(q, kk, v, cfg, q_tile=128)
        o.block_until_ready()
        us = (time.time() - t0) * 1e6
        oref = M.moba_attention_reference(q, kk, v, cfg)
        err = float(jnp.abs(o - oref).max())
        flops = 2 * 2 * n * k * bs * d * 2 + 2 * n * (n // bs) * d * 2
        rows.append((f"flash_moba_N{n}_B{bs}", us,
                     f"maxerr={err:.1e};flops={flops:.2e}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(r)
