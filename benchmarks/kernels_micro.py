"""Pallas kernel microbenchmarks (interpret-mode correctness + op counts).

Wall-time in interpret mode is not meaningful for TPU perf; what this
records is that each kernel runs and matches its oracle at benchmark
shapes, plus the analytic FLOPs each kernel performs (the §Roofline
compute-side inputs for the kernel path).

``--json out.json`` writes the same stable schema family as
``decode_micro`` (per-case shapes, wall time, agreement vs the
reference oracle, analytic FLOPs); the process exits non-zero when any
case disagrees beyond ``AGREE_TOL``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import ops
from repro.kernels.runtime import resolve_interpret

SCHEMA_VERSION = 1
AGREE_TOL = 5e-3
SHAPES = [(512, 64, 2, 64), (1024, 128, 2, 64)]    # (n, bs, top_k, d)
SMOKE_SHAPES = [(256, 32, 2, 32)]


def run_cases(shapes):
    cases = []
    for (n, bs, k, d) in shapes:
        cfg = MoBAConfig(block_size=bs, top_k=k)
        keys = jax.random.split(jax.random.PRNGKey(n), 3)
        q = jax.random.normal(keys[0], (1, 2, n, d), jnp.float32) * 0.5
        kk = jax.random.normal(keys[1], (1, 1, n, d), jnp.float32) * 0.5
        v = jax.random.normal(keys[2], (1, 1, n, d), jnp.float32)
        t0 = time.perf_counter()
        o = ops.flash_moba(q, kk, v, cfg, q_tile=128)
        o.block_until_ready()
        wall_us = (time.perf_counter() - t0) * 1e6
        oref = M.moba_attention_reference(q, kk, v, cfg)
        err = float(jnp.abs(o - oref).max())
        flops = 2 * 2 * n * k * bs * d * 2 + 2 * n * (n // bs) * d * 2
        cases.append({
            "name": f"flash_moba_N{n}_B{bs}",
            "shape": {"batch": 1, "heads": 2, "kv_heads": 1,
                      "head_dim": d, "seq_len": n, "block_size": bs,
                      "top_k": k},
            "wall_us": wall_us,
            "flops": flops,
            "max_abs_diff_vs_reference": err,
            "agree_tol": AGREE_TOL,
            "agree": err <= AGREE_TOL,
        })
    return cases


def _report(cases):
    return {
        "benchmark": "kernels_micro",
        "schema_version": SCHEMA_VERSION,
        "dtype": "float32",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "interpret": resolve_interpret(None),
        "agree_tol": AGREE_TOL,
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def bench():
    """run.py hook: flatten the JSON cases into its CSV row format."""
    return [(c["name"], c["wall_us"],
             f"maxerr={c['max_abs_diff_vs_reference']:.1e};"
             f"flops={c['flops']:.2e}")
            for c in run_cases(SHAPES)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here")
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape only (CI)")
    args = ap.parse_args(argv)
    cases = run_cases(SMOKE_SHAPES if args.smoke else SHAPES)
    report = _report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for c in cases:
        print(f"{c['name']},{c['wall_us']:.1f},"
              f"maxerr={c['max_abs_diff_vs_reference']:.1e};"
              f"flops={c['flops']:.2e}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"ORACLE DISAGREEMENT beyond {AGREE_TOL}: {bad}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
