"""Training-kernel microbenchmarks: grouped/tiled vs legacy flat grids.

Runs the full FlashMoBA pipeline (centroids → flash_topk → layout →
moba_fwd → merge) per benchmark shape through both kernel grids — the
MXU-tiled ``grouped`` grids (grouped-GQA topk + kb-tiled fwd) and the
legacy ``flat`` grids — against the O(N²) reference oracle.  Wall-time
in interpret mode is not meaningful for TPU perf; the recorded signal is
(a) both grids match the oracle at benchmark shapes and (b) the analytic
per-pipeline FLOPs and HBM bytes (the §Roofline inputs for the training
path).

Analytic HBM accounting (``itemsize`` = input dtype bytes, stats fp32):

  centroids   read K once, write per-block centroids:
              Hkv·(N + nb)·d·isz
  topk        Q tiles fetched once per (qt) step (resident across the
              ct sweep) + the streamed centroid tiles + the (N, k)
              selection write.  The centroid stream is where the grids
              differ: the flat grid re-fetches each (C, d) tile for
              every *query* head — H·(N/Tq)·nct·C·d·isz — while the
              grouped grid fetches it once per *kv* head (one DMA
              serves the whole GQA group): Hkv·(N/Tq)·nct·C·d·isz,
              exactly 1/G of the flat traffic (``topk_cent_bytes``).
  fwd         sorted Q + positions in, per-tile K/V stream (each tile
              re-reads its block: (L/Tq)·B·d·isz·2 per head — kb
              tiling changes DMA granularity, not total bytes), and the
              (o, m, l) fp32 partials out.

``--json out.json`` writes the stable machine-readable schema consumed
by the CI ``bench-smoke`` job and the committed ``BENCH_kernels.json``
snapshot (same family as ``decode_micro``): per-case shapes and
per-path ``wall_us`` / ``flops`` / ``hbm_bytes`` / ``topk_cent_bytes``
/ ``max_abs_diff_vs_reference``, plus a top-level ``agree`` verdict.
Exits non-zero when any path disagrees beyond its dtype tolerance.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import ops
from repro.kernels.runtime import resolve_interpret

SCHEMA_VERSION = 2
AGREE_TOL = 5e-3
TOLS = {"float32": 5e-3, "bfloat16": 3e-2}
ITERS = 3
Q_TILE = 128
CENT_TILE = 128

# (n, bs, top_k, d, h, hkv, dtype) — groups G = h/hkv ∈ {1, 2, 4},
# block sizes spanning the paper's small-block regime.  The smoke shape
# leads the list so the CI gate can match it against this snapshot.
SHAPES = [
    (256, 32, 2, 32, 4, 2, "float32"),
    (512, 32, 8, 64, 4, 2, "float32"),
    (512, 64, 4, 64, 4, 1, "float32"),
    (1024, 128, 2, 64, 2, 2, "float32"),
    (512, 32, 8, 64, 4, 1, "bfloat16"),
    (512, 64, 4, 64, 2, 2, "bfloat16"),
]
SMOKE_SHAPES = SHAPES[:1]


def _flops(*, n, bs, k, d, h):
    """Route matmul (N × nb × d per head) + gathered attention
    (QKᵀ and PV over the N·k routed pairs)."""
    nb = -(-n // bs)
    return h * (2 * n * nb * d + 2 * 2 * n * k * bs * d)


def _hbm_bytes(grid, *, n, bs, k, d, h, hkv, isz):
    """Analytic per-pipeline HBM bytes for one grid (see module doc)."""
    nb = -(-n // bs)
    nct = -(-nb // CENT_TILE)
    tile = min(Q_TILE, n)
    L = n * k + nb * tile                       # varlen layout capacity
    cents = hkv * (n + nb) * d * isz
    q_read = h * n * d * isz
    steps = (n // tile) * nct
    cent_rows = hkv if grid == "pallas_grouped" else h
    topk_cent = cent_rows * steps * CENT_TILE * d * isz
    sel = h * n * k * 4
    fwd = h * (L * (d * isz + 4)                # sorted Q + positions
               + (L // tile) * bs * d * isz * 2  # per-tile K/V stream
               + L * (d + 2) * 4)               # (o, m, l) fp32 out
    return {"hbm_bytes": cents + q_read + topk_cent + sel + fwd,
            "topk_cent_bytes": topk_cent}


def run_cases(shapes):
    cases = []
    for (n, bs, k, d, h, hkv, dtype) in shapes:
        cfg = MoBAConfig(block_size=bs, top_k=k)
        dt = jnp.dtype(dtype)
        keys = jax.random.split(jax.random.PRNGKey(n + bs + h), 3)
        q = jax.random.normal(keys[0], (1, h, n, d), dt) * 0.5
        kk = jax.random.normal(keys[1], (1, hkv, n, d), dt) * 0.5
        v = jax.random.normal(keys[2], (1, hkv, n, d), dt)
        oref = M.moba_attention_reference(q, kk, v, cfg)
        tol = TOLS[dtype]
        g = h // hkv

        paths = {}
        for pname, grid in (("pallas_grouped", "grouped"),
                            ("pallas_flat", "flat")):
            fn = jax.jit(lambda q, kk, v, c=cfg, gr=grid:
                         ops.flash_moba(q, kk, v, c, q_tile=Q_TILE,
                                        grid=gr))
            o = fn(q, kk, v).block_until_ready()      # compile + check
            err = float(jnp.abs(o.astype(jnp.float32)
                                - oref.astype(jnp.float32)).max())
            t0 = time.perf_counter()
            for _ in range(ITERS):
                fn(q, kk, v).block_until_ready()
            wall_us = (time.perf_counter() - t0) / ITERS * 1e6
            paths[pname] = {
                "wall_us": wall_us,
                "flops": _flops(n=n, bs=bs, k=k, d=d, h=h),
                "max_abs_diff_vs_reference": err,
                **_hbm_bytes(pname, n=n, bs=bs, k=k, d=d, h=h, hkv=hkv,
                             isz=dt.itemsize),
            }
        cases.append({
            "name": f"flash_moba_N{n}_B{bs}_G{g}_{dtype}",
            "shape": {"batch": 1, "heads": h, "kv_heads": hkv,
                      "head_dim": d, "seq_len": n, "block_size": bs,
                      "top_k": k, "dtype": dtype, "group": g},
            "agree_tol": tol,
            "agree": all(p["max_abs_diff_vs_reference"] <= tol
                         for p in paths.values()),
            "paths": paths,
        })
    return cases


def _report(cases):
    return {
        "benchmark": "kernels_micro",
        "schema_version": SCHEMA_VERSION,
        "dtype": "mixed",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "interpret": resolve_interpret(None),
        "agree_tol": AGREE_TOL,
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def bench():
    """run.py hook: flatten the JSON cases into its CSV row format."""
    rows = []
    for case in run_cases(SHAPES):
        for pname, p in case["paths"].items():
            rows.append((f"{case['name']}_{pname}", p["wall_us"],
                         f"maxerr={p['max_abs_diff_vs_reference']:.1e};"
                         f"flops={p['flops']:.2e};"
                         f"hbm_bytes={p['hbm_bytes']:.2e}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here "
                         "(the BENCH_kernels.json schema)")
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape only (the CI bench-smoke leg)")
    args = ap.parse_args(argv)
    cases = run_cases(SMOKE_SHAPES if args.smoke else SHAPES)
    report = _report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for case in cases:
        for pname, p in case["paths"].items():
            print(f"{case['name']}_{pname},{p['wall_us']:.1f},"
                  f"maxerr={p['max_abs_diff_vs_reference']:.1e};"
                  f"flops={p['flops']:.2e};"
                  f"hbm_bytes={p['hbm_bytes']:.2e}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"ORACLE DISAGREEMENT: {bad}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
