"""Paper Tables 1/2 — LM quality: dense vs MoBA-{512,256,128} ± kconv.

The paper trains 340M/1B on 100B tokens; on CPU we reproduce the *trend*
at reduced scale: same hybrid architecture family (swa/moba interleave),
synthetic Markov corpus, a few hundred steps, comparing final train loss.
The paper's claim under test: small-B MoBA ≈ dense quality; kconv helps.
"""
from __future__ import annotations

import time

import numpy as np



def run(steps: int = 120, batch: int = 8, seq: int = 256, seed: int = 0):
    variants = [
        ("dense", dict(dense_baseline=True)),
        ("moba-B64", dict(block_size=64, top_k=2)),
        ("moba-B32", dict(block_size=32, top_k=4)),
        ("moba-B16", dict(block_size=16, top_k=8)),
        ("moba-B16+kconv3", dict(block_size=16, top_k=8,
                                 key_conv_width=3)),
    ]
    # scaled-down (B, k) ladder keeps the paper's constant-sparsity design:
    # k/nb == 1/8 at seq 256 ⇔ (64,2),(32,4),(16,8) — exactly Table 1's
    # {512/2, 256/4, 128/8} pattern at 1/16 scale.
    results = []
    for name, kw in variants:
        from repro import configs
        import dataclasses
        from repro.configs.base import AttentionConfig, MoBAConfig
        from repro.models import transformer as T
        from repro.optim import adamw
        from repro.configs.base import TrainConfig
        from repro.data.pipeline import DataConfig, SyntheticLM
        import jax, jax.numpy as jnp

        dense = kw.pop("dense_baseline", False)
        moba = MoBAConfig(block_size=kw.get("block_size", 16),
                          top_k=kw.get("top_k", 2),
                          key_conv_width=kw.get("key_conv_width", 0))
        cfg = dataclasses.replace(
            configs.get_smoke_config("moba-340m"),
            num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
            head_dim=32, d_ff=352, vocab_size=512,
            attention=AttentionConfig(kind="moba", moba=moba, window=32,
                                      rope_on_moba=False),
            layer_pattern=("swa", "dense") if dense else ("swa", "moba"))
        tcfg = TrainConfig(global_batch_size=batch, seq_len=seq,
                           learning_rate=3e-3, total_steps=steps,
                           warmup_steps=10, seed=seed)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=seq, global_batch=batch,
                                      seed=seed))
        from repro.launch import steps as S
        params = T.init_lm(jax.random.PRNGKey(seed), cfg)
        opt = adamw.adamw_init(params)
        step_fn = jax.jit(S.make_train_step(cfg, tcfg,
                                            backend="sparse"),
                          donate_argnums=(0, 1))
        losses = []
        for s in range(steps):
            b = {"tokens": jnp.asarray(data.batch_at(s)["tokens"])}
            params, opt, m = step_fn(params, opt, b)
            losses.append(float(m["loss"]))
        final = float(np.mean(losses[-10:]))
        results.append((name, final))
        print(f"{name:<18} final loss {final:.4f}")
    return results


def bench():
    t0 = time.time()
    results = run(steps=60, batch=4, seq=256)
    us = (time.time() - t0) * 1e6 / len(results)
    dense = dict(results)["dense"]
    best_moba = min(v for k, v in results if k != "dense")
    return [("table12_lm_quality", us,
             f"dense={dense:.3f};best_moba={best_moba:.3f}")]


if __name__ == "__main__":
    run()
