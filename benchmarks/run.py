"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the §Roofline pointer:
the 40-cell roofline table comes from ``repro.launch.dryrun`` because it
needs 512 placeholder devices — run separately).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (decode_micro, fig2_snr, fig3_efficiency,
                            fig4_breakdown, kernels_micro,
                            serve_throughput, table12_lm, table34_niah)
    mods = [fig2_snr, table12_lm, table34_niah, fig3_efficiency,
            fig4_breakdown, kernels_micro, decode_micro, serve_throughput]
    rows = []
    failed = []
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        print(f"=== {name} ===", file=sys.stderr)
        try:
            rows.extend(mod.bench())
        except Exception as e:
            failed.append((name, repr(e)))
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"{len(failed)} benchmark(s) FAILED: {failed}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
