"""Paper Fig. 2 / Eq. 3 — empirical validation of the SNR model.

Plants a signal key among noise keys (App. A's generative model), measures
the router's retrieval failure rate, and compares to Φ(−SNR) with
SNR = Δμ_eff · sqrt(d / 2B).  This validates the paper's central equation
directly — the block-size and clustering (m, μ_cluster) effects both.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import snr as S


def run(trials: int = 400, n_tokens: int = 4096, seed: int = 0):
    rows = []
    print("# fig2_snr: empirical p(signal block ranked top-k) vs theory")
    print(f"{'d':>5}{'B':>6}{'m':>3}{'mu_c':>6}{'SNR':>8}"
          f"{'p_fail_theory':>15}{'p_fail_emp':>12}")
    for d, bs, m, mu_c, delta in [
        (64, 512, 1, 0.0, 0.6), (64, 256, 1, 0.0, 0.6),
        (64, 128, 1, 0.0, 0.6), (64, 64, 1, 0.0, 0.6),
        (128, 128, 1, 0.0, 0.6), (32, 128, 1, 0.0, 0.6),
        (64, 128, 4, 0.3, 0.6), (64, 128, 8, 0.3, 0.6),
    ]:
        eff = S.effective_gap(delta, m=m, mu_cluster=mu_c, mu_noise=0.0)
        theory_snr = S.snr(d, bs, eff)
        # theory: p(noise block beats signal). empirical: top-1 retrieval
        # failure among nb blocks ≈ 1-(1-p)^(nb-1) for small p; we compare
        # per-pair failure via rank of the signal block.
        fails = 0
        pairs = 0
        key = jax.random.PRNGKey(seed)
        for t in range(trials):
            key, k2 = jax.random.split(key)
            prob = S.make_planted_problem(k2, n_tokens, d, bs, delta,
                                          m=m, mu_cluster=mu_c,
                                          signal_block=t % (n_tokens // bs))
            nb = n_tokens // bs
            cents = prob.keys.reshape(nb, bs, d).mean(axis=1)
            scores = np.asarray(cents @ prob.q)
            sig = scores[prob.signal_block]
            noise = np.delete(scores, prob.signal_block)
            fails += int((noise > sig).sum())
            pairs += nb - 1
        emp = fails / pairs
        theory = S.p_fail(d, bs, eff)
        rows.append((d, bs, m, mu_c, theory_snr, theory, emp))
        print(f"{d:>5}{bs:>6}{m:>3}{mu_c:>6.1f}{theory_snr:>8.3f}"
              f"{theory:>15.4f}{emp:>12.4f}")
    return rows


def bench():
    """CSV rows for benchmarks.run."""
    t0 = time.time()
    rows = run(trials=120, n_tokens=2048)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    # derived: max |empirical - theory| — the validation metric
    err = max(abs(r[-1] - r[-2]) for r in rows)
    return [("fig2_snr_validation", us, f"max|emp-theory|={err:.4f}")]


if __name__ == "__main__":
    run()
