"""Paged-decode microbenchmark: XLA gather-and-densify vs fused Pallas.

Runs one decode-attention step (routing + page gather + attend) against a
populated page pool across context lengths × block sizes, for three
paths: the XLA gather path (`core.moba.moba_paged_decode_attention`),
the grouped MXU-tiled Pallas kernel and the legacy flat Pallas grid
(`kernels.moba_decode`, DESIGN.md §5).  As with ``kernels_micro``,
interpret-mode wall time is not TPU-meaningful; the recorded signal is
(a) the paths agree at benchmark shapes and (b) the analytic per-step
HBM bytes each path moves — the §Roofline memory-side input for decode.

Analytic HBM accounting (fp32 = 4 bytes, K and V both counted):

  route            every path reads the B·npg·Hkv·d centroid gather
  xla              gathers per *query* head with no dedup — source
                   reads + the densified (B,H,k,ps,d) copy written then
                   re-read: 3 × B·H·k·ps·d·8
  pallas_flat      per-(query head, slot) page streamed once from the
                   pool: B·H·k·ps·d·8
  pallas_grouped   per-kv-head deduplicated union of the group's pages
                   (Σ n_uniq, measured from the actual routing):
                   Σ n_uniq·ps·d·8

``--json out.json`` writes the stable machine-readable schema consumed
by the CI ``bench-smoke`` job (see ``_report``): shapes, per-path
``hbm_bytes`` / ``wall_us`` / ``max_abs_diff_vs_xla``, and a top-level
``agree`` verdict.  The process exits non-zero when any path disagrees
with the XLA oracle beyond ``AGREE_TOL``, so the CI leg fails on
numerical drift, not just on crashes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import moba_decode as MD
from repro.kernels.runtime import resolve_interpret

SCHEMA_VERSION = 1
AGREE_TOL = 1e-3
ITERS = 3
SHAPES = [(512, 64, 4), (1024, 64, 4), (1024, 128, 4)]   # (ctx, ps, top_k)
SMOKE_SHAPES = [(256, 32, 2)]


def _build_pool(rng, b, n_ctx, hkv, d, ps):
    npg = -(-n_ctx // ps)
    num_pages = b * npg
    kv_lens = np.full((b,), n_ctx, np.int32)
    kv_lens[1:] = rng.integers(max(1, n_ctx // 4), n_ctx, size=b - 1)
    perm = rng.permutation(num_pages)
    table = np.full((b, npg), -1, np.int32)
    pos = 0
    for i in range(b):
        need = -(-int(kv_lens[i]) // ps)
        table[i, :need] = perm[pos:pos + need]
        pos += need
    from repro.serving import paged_cache as PC
    cache = {"pages_k": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "pages_v": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    cache = PC.paged_append_prefill(cache, jnp.asarray(table),
                                    jnp.asarray(kv_lens), kc, vc)
    return cache, jnp.asarray(table), jnp.asarray(kv_lens)


def _hbm_bytes(path, *, b, h, hkv, d, ps, tk, npg, union_pages):
    route = b * npg * hkv * d * 4
    per_head = b * h * tk * ps * d * 4 * 2            # K and V, no dedup
    if path == "xla":
        return route + 3 * per_head                   # src + copy w/r
    if path == "pallas_flat":
        return route + per_head
    if path == "pallas_grouped":
        return route + union_pages * ps * d * 4 * 2
    raise ValueError(path)


def run_cases(shapes):
    cases = []
    b, h, hkv, d = 4, 4, 2, 64
    for (n_ctx, ps, tk) in shapes:
        cfg = MoBAConfig(block_size=ps, top_k=tk)
        rng = np.random.default_rng(n_ctx + ps)
        cache, table, kv_lens = _build_pool(rng, b, n_ctx, hkv, d, ps)
        q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
        args = (q, cache["pages_k"], cache["pages_v"], cache["centroids"],
                table, kv_lens)
        npg = table.shape[1]

        # measured union size: the grouped grid's realized page count
        idx, sel_valid = M.moba_paged_route(q, cache["centroids"], table,
                                            kv_lens, cfg, page_size=ps)
        _, n_uniq = MD.union_pages(idx, sel_valid, npg)
        union_pages = int(jnp.sum(n_uniq))

        fns = {
            "xla": jax.jit(
                lambda *a, c=cfg: M.moba_paged_decode_attention(*a, c)),
            "pallas_grouped": jax.jit(
                lambda *a, c=cfg: MD.moba_paged_decode_pallas(
                    *a, c, grid="grouped")),
            "pallas_flat": jax.jit(
                lambda *a, c=cfg: MD.moba_paged_decode_pallas(
                    *a, c, grid="flat")),
        }
        outs = {name: np.asarray(fn(*args).block_until_ready())
                for name, fn in fns.items()}
        active = np.asarray(kv_lens) > 0  # kv_len==0 rows: kernels emit
        #                                   zeros, XLA emits garbage

        paths = {}
        for name, fn in fns.items():
            t0 = time.perf_counter()
            for _ in range(ITERS):
                fn(*args).block_until_ready()
            wall_us = (time.perf_counter() - t0) / ITERS * 1e6
            err = float(np.abs(outs[name][active]
                               - outs["xla"][active]).max())
            paths[name] = {
                "wall_us": wall_us,
                "hbm_bytes": _hbm_bytes(name, b=b, h=h, hkv=hkv, d=d,
                                        ps=ps, tk=tk, npg=npg,
                                        union_pages=union_pages),
                "max_abs_diff_vs_xla": err,
            }
        cases.append({
            "name": f"paged_decode_N{n_ctx}_B{ps}",
            "shape": {"batch": b, "heads": h, "kv_heads": hkv,
                      "head_dim": d, "ctx": n_ctx, "page_size": ps,
                      "top_k": tk, "pages_per_seq": npg},
            "union_pages": union_pages,
            "agree_tol": AGREE_TOL,
            "agree": all(p["max_abs_diff_vs_xla"] <= AGREE_TOL
                         for p in paths.values()),
            "paths": paths,
        })
    return cases


def _report(cases):
    return {
        "benchmark": "decode_micro",
        "schema_version": SCHEMA_VERSION,
        "dtype": "float32",
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "interpret": resolve_interpret(None),
        "agree_tol": AGREE_TOL,
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def bench():
    """run.py hook: flatten the JSON cases into its CSV row format."""
    rows = []
    for case in run_cases(SHAPES):
        for pname, p in case["paths"].items():
            rows.append((f"{case['name']}_{pname}", p["wall_us"],
                         f"maxerr={p['max_abs_diff_vs_xla']:.1e};"
                         f"hbm_bytes={p['hbm_bytes']:.2e}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here "
                         "(the BENCH_decode.json schema)")
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape only (the CI bench-smoke leg)")
    args = ap.parse_args(argv)
    cases = run_cases(SMOKE_SHAPES if args.smoke else SHAPES)
    report = _report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for case in cases:
        for pname, p in case["paths"].items():
            print(f"{case['name']}_{pname},{p['wall_us']:.1f},"
                  f"maxerr={p['max_abs_diff_vs_xla']:.1e};"
                  f"hbm_bytes={p['hbm_bytes']:.2e}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"PATH DISAGREEMENT beyond {AGREE_TOL}: {bad}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
