"""Paged-decode microbenchmark: XLA gather-and-densify vs fused Pallas.

Runs one decode-attention step (routing + page gather + attend) against a
populated page pool across context lengths × block sizes × K/V storage
dtypes, for three paths: the XLA gather path
(`core.moba.moba_paged_decode_attention`), the grouped MXU-tiled Pallas
kernel and the legacy flat Pallas grid (`kernels.moba_decode`,
DESIGN.md §5).  As with ``kernels_micro``, interpret-mode wall time is
not TPU-meaningful; the recorded signal is (a) the paths agree at
benchmark shapes and (b) the analytic per-step HBM bytes each path
moves — the §Roofline memory-side input for decode.

The ``--kv-dtype`` axis stores the page pool quantized (int8 / fp8 with
per-(page, kv head) fp32 scales, ``core/quantization.py``) and measures
every path against the *fp32* XLA oracle on the same underlying K/V —
so ``max_abs_diff_vs_xla`` for a quantized case is the end-to-end
quantization error, gated per dtype.  Centroids (and hence routing)
stay fp32, so the selected pages are identical across dtypes and the
HBM savings are pure payload-byte savings.

Analytic HBM accounting (K and V both counted; ``esize`` = payload
bytes/elt: 4 fp32, 1 int8/fp8; quantized paths add the per-page fp32
scale reads, and the XLA densify copy is always written/re-read at
fp32):

  route            every path reads the B·npg·Hkv·d fp32 centroid gather
  xla              gathers per *query* head with no dedup — source
                   reads at esize + the densified fp32 (B,H,k,ps,d)
                   copy written then re-read
  pallas_flat      per-(query head, slot) page streamed once from the
                   pool at esize
  pallas_grouped   per-kv-head deduplicated union of the group's pages
                   (Σ n_uniq, measured from the actual routing):
                   Σ n_uniq·ps·d·esize·2

``--json out.json`` writes the stable machine-readable schema consumed
by the CI ``bench-smoke`` job (see ``_report``): shapes, per-path
``hbm_bytes`` / ``wall_us`` / ``max_abs_diff_vs_xla``, per-case
``kv_dtype``, and a top-level ``agree`` verdict.  The process exits
non-zero when any path disagrees with the fp32 XLA oracle beyond its
dtype's ``AGREE_TOL``, so the CI leg fails on numerical drift, not just
on crashes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.core import quantization as Q
from repro.kernels import moba_decode as MD
from repro.kernels.runtime import resolve_interpret

SCHEMA_VERSION = 2
# per-dtype path-vs-fp32-oracle ceilings; fp32 is pure kernel drift,
# int8/fp8 budgets the quantization error at the benchmark shapes
AGREE_TOL = {"fp32": 1e-3, "int8": 5e-2, "fp8": 2e-1}
ITERS = 3
SHAPES = [(512, 64, 4), (1024, 64, 4), (1024, 128, 4)]   # (ctx, ps, top_k)
SMOKE_SHAPES = [(256, 32, 2)]


def _build_pool(rng, b, n_ctx, hkv, d, ps, kv_dtype="fp32"):
    npg = -(-n_ctx // ps)
    num_pages = b * npg
    kv_lens = np.full((b,), n_ctx, np.int32)
    kv_lens[1:] = rng.integers(max(1, n_ctx // 4), n_ctx, size=b - 1)
    perm = rng.permutation(num_pages)
    table = np.full((b, npg), -1, np.int32)
    pos = 0
    for i in range(b):
        need = -(-int(kv_lens[i]) // ps)
        table[i, :need] = perm[pos:pos + need]
        pos += need
    from repro.serving import paged_cache as PC
    pg_dtype = (jnp.float32 if kv_dtype == "fp32"
                else Q.payload_dtype(kv_dtype))
    cache = {"pages_k": jnp.zeros((num_pages, ps, hkv, d), pg_dtype),
             "pages_v": jnp.zeros((num_pages, ps, hkv, d), pg_dtype),
             "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}
    if kv_dtype != "fp32":
        cache["scales_k"] = jnp.ones((num_pages, hkv), jnp.float32)
        cache["scales_v"] = jnp.ones((num_pages, hkv), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    cache = PC.paged_append_prefill(cache, jnp.asarray(table),
                                    jnp.asarray(kv_lens), kc, vc)
    return cache, jnp.asarray(table), jnp.asarray(kv_lens)


def _hbm_bytes(path, *, b, h, hkv, d, ps, tk, npg, union_pages, esize):
    route = b * npg * hkv * d * 4
    scales = 0 if esize == 4 else union_pages * hkv * 4 * 2
    per_head_src = b * h * tk * ps * d * esize * 2    # K and V, no dedup
    per_head_f32 = b * h * tk * ps * d * 4 * 2
    if path == "xla":
        return route + scales + per_head_src + 2 * per_head_f32
    if path == "pallas_flat":
        return route + scales + per_head_src
    if path == "pallas_grouped":
        return route + scales + union_pages * ps * d * esize * 2
    raise ValueError(path)


def run_cases(shapes, kv_dtypes=("fp32",)):
    cases = []
    b, h, hkv, d = 4, 4, 2, 64
    for (n_ctx, ps, tk) in shapes:
        cfg = MoBAConfig(block_size=ps, top_k=tk)
        # same seed per shape across dtypes: identical underlying K/V,
        # so the fp32 XLA output is the oracle for every dtype's paths
        cache0, table, kv_lens = _build_pool(
            np.random.default_rng(n_ctx + ps), b, n_ctx, hkv, d, ps)
        rng = np.random.default_rng(n_ctx + ps + 1)
        q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
        npg = table.shape[1]
        oracle_fn = jax.jit(
            lambda *a, c=cfg: M.moba_paged_decode_attention(*a, c))
        oracle = np.asarray(oracle_fn(
            q, cache0["pages_k"], cache0["pages_v"], cache0["centroids"],
            table, kv_lens).block_until_ready())
        active = np.asarray(kv_lens) > 0  # kv_len==0 rows: kernels emit
        #                                   zeros, XLA emits garbage

        # measured union size: the grouped grid's realized page count
        # (routing is fp32 in every mode, so one measurement serves all)
        idx, sel_valid = M.moba_paged_route(q, cache0["centroids"], table,
                                            kv_lens, cfg, page_size=ps)
        _, n_uniq = MD.union_pages(idx, sel_valid, npg)
        union_pages = int(jnp.sum(n_uniq))

        for kv_dtype in kv_dtypes:
            cache = cache0 if kv_dtype == "fp32" else _build_pool(
                np.random.default_rng(n_ctx + ps), b, n_ctx, hkv, d, ps,
                kv_dtype)[0]
            sk, sv = cache.get("scales_k"), cache.get("scales_v")
            esize = jnp.dtype(cache["pages_k"].dtype).itemsize
            kw = {"scales_k": sk, "scales_v": sv}
            fns = {
                "xla": jax.jit(lambda *a, c=cfg:
                               M.moba_paged_decode_attention(*a, c, **kw)),
                "pallas_grouped": jax.jit(
                    lambda *a, c=cfg: MD.moba_paged_decode_pallas(
                        *a, c, grid="grouped", **kw)),
                "pallas_flat": jax.jit(
                    lambda *a, c=cfg: MD.moba_paged_decode_pallas(
                        *a, c, grid="flat", **kw)),
            }
            args = (q, cache["pages_k"], cache["pages_v"],
                    cache["centroids"], table, kv_lens)
            outs = {name: np.asarray(fn(*args).block_until_ready())
                    for name, fn in fns.items()}

            paths = {}
            for name, fn in fns.items():
                t0 = time.perf_counter()
                for _ in range(ITERS):
                    fn(*args).block_until_ready()
                wall_us = (time.perf_counter() - t0) / ITERS * 1e6
                err = float(np.abs(outs[name][active]
                                   - oracle[active]).max())
                paths[name] = {
                    "wall_us": wall_us,
                    "hbm_bytes": _hbm_bytes(name, b=b, h=h, hkv=hkv, d=d,
                                            ps=ps, tk=tk, npg=npg,
                                            union_pages=union_pages,
                                            esize=esize),
                    "max_abs_diff_vs_xla": err,
                }
            tol = AGREE_TOL[kv_dtype]
            suffix = "" if kv_dtype == "fp32" else f"_{kv_dtype}"
            cases.append({
                "name": f"paged_decode_N{n_ctx}_B{ps}{suffix}",
                "kv_dtype": kv_dtype,
                "shape": {"batch": b, "heads": h, "kv_heads": hkv,
                          "head_dim": d, "ctx": n_ctx, "page_size": ps,
                          "top_k": tk, "pages_per_seq": npg},
                "union_pages": union_pages,
                "agree_tol": tol,
                "agree": all(p["max_abs_diff_vs_xla"] <= tol
                             for p in paths.values()),
                "paths": paths,
            })
    return cases


def _report(cases):
    return {
        "benchmark": "decode_micro",
        "schema_version": SCHEMA_VERSION,
        "dtype": "float32",
        "kv_dtypes": sorted({c["kv_dtype"] for c in cases}),
        "jax_version": jax.__version__,
        "device": jax.default_backend(),
        "interpret": resolve_interpret(None),
        "agree_tol": AGREE_TOL,
        "agree": all(c["agree"] for c in cases),
        "cases": cases,
    }


def bench():
    """run.py hook: flatten the JSON cases into its CSV row format."""
    rows = []
    for case in run_cases(SHAPES):
        for pname, p in case["paths"].items():
            rows.append((f"{case['name']}_{pname}", p["wall_us"],
                         f"maxerr={p['max_abs_diff_vs_xla']:.1e};"
                         f"hbm_bytes={p['hbm_bytes']:.2e}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="OUT",
                    help="write the machine-readable report here "
                         "(the BENCH_decode.json schema)")
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape only (the CI bench-smoke leg)")
    ap.add_argument("--shapes", choices=["full", "smoke", "all"],
                    default=None,
                    help="shape set (default full; --smoke implies "
                         "smoke; 'all' = full + smoke, used to "
                         "regenerate the committed snapshot so smoke "
                         "runs always find their cases in it)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=sorted(AGREE_TOL) + ["all"],
                    help="K/V page-pool storage dtype axis ('all' runs "
                         "every dtype; quantized pools are measured "
                         "against the fp32 XLA oracle)")
    args = ap.parse_args(argv)
    shape_set = args.shapes or ("smoke" if args.smoke else "full")
    shapes = {"full": SHAPES, "smoke": SMOKE_SHAPES,
              "all": SHAPES + SMOKE_SHAPES}[shape_set]
    kv_dtypes = (tuple(sorted(AGREE_TOL)) if args.kv_dtype == "all"
                 else (args.kv_dtype,))
    cases = run_cases(shapes, kv_dtypes)
    report = _report(cases)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    for case in cases:
        for pname, p in case["paths"].items():
            print(f"{case['name']}_{pname},{p['wall_us']:.1f},"
                  f"maxerr={p['max_abs_diff_vs_xla']:.1e};"
                  f"hbm_bytes={p['hbm_bytes']:.2e}")
    if not report["agree"]:
        bad = [c["name"] for c in cases if not c["agree"]]
        print(f"PATH DISAGREEMENT beyond per-dtype tolerance: {bad}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
