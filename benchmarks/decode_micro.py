"""Paged-decode microbenchmark: XLA gather-and-densify vs fused Pallas.

Runs one decode-attention step (routing + page gather + attend) against a
populated page pool across context lengths × block sizes, for both the
XLA path (`core.moba.moba_paged_decode_attention`) and the fused
scalar-prefetched Pallas kernel (`kernels.moba_decode`).  As with
``kernels_micro``, interpret-mode wall time is not TPU-meaningful; the
recorded signal is (a) the two paths agree at benchmark shapes and (b)
the analytic per-step HBM bytes each path moves (the XLA path
materializes the (B,Hkv,G,1,k,ps,d) gather in HBM; the kernel streams
pages once), which is the §Roofline memory-side input for decode.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoBAConfig
from repro.core import moba as M
from repro.kernels import moba_decode as MD


def _build_pool(rng, b, n_ctx, hkv, d, ps):
    npg = -(-n_ctx // ps)
    num_pages = b * npg
    kv_lens = np.full((b,), n_ctx, np.int32)
    kv_lens[1:] = rng.integers(max(1, n_ctx // 4), n_ctx, size=b - 1)
    perm = rng.permutation(num_pages)
    table = np.full((b, npg), -1, np.int32)
    pos = 0
    for i in range(b):
        need = -(-int(kv_lens[i]) // ps)
        table[i, :need] = perm[pos:pos + need]
        pos += need
    from repro.serving import paged_cache as PC
    cache = {"pages_k": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "pages_v": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    cache = PC.paged_append_prefill(cache, jnp.asarray(table),
                                    jnp.asarray(kv_lens), kc, vc)
    return cache, jnp.asarray(table), jnp.asarray(kv_lens)


def bench():
    rows = []
    b, h, hkv, d = 4, 4, 2, 64
    for (n_ctx, bs, tk) in [(512, 64, 4), (1024, 64, 4), (1024, 128, 4)]:
        cfg = MoBAConfig(block_size=bs, top_k=tk)
        rng = np.random.default_rng(n_ctx + bs)
        cache, table, kv_lens = _build_pool(rng, b, n_ctx, hkv, d, bs)
        q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
        args = (q, cache["pages_k"], cache["pages_v"], cache["centroids"],
                table, kv_lens, cfg)

        xla_fn = jax.jit(lambda *a: M.moba_paged_decode_attention(*a, cfg))
        pl_fn = jax.jit(lambda *a: MD.moba_paged_decode_pallas(*a, cfg))
        o_x = xla_fn(*args[:-1]).block_until_ready()
        o_p = pl_fn(*args[:-1]).block_until_ready()
        err = float(jnp.abs(o_x - o_p).max())

        for name, fn in (("xla", xla_fn), ("pallas", pl_fn)):
            t0 = time.time()
            for _ in range(3):
                fn(*args[:-1]).block_until_ready()
            us = (time.time() - t0) / 3 * 1e6
            npg = table.shape[1]
            # per-step HBM bytes (fp32): routing reads + page reads, plus
            # the densified gather copy the XLA path writes and re-reads
            route = b * npg * hkv * d * 4
            pages = b * hkv * tk * bs * d * 4 * 2          # K and V
            gather = pages * 2 * (h // hkv) if name == "xla" else 0
            rows.append((f"paged_decode_{name}_N{n_ctx}_B{bs}", us,
                         f"maxerr={err:.1e};hbm_bytes={route+pages+gather:.2e}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(r)
