"""CI gate: analytic ``hbm_bytes`` must not regress vs a committed snapshot.

Compares a freshly generated benchmark JSON (typically a ``--smoke`` run
from the bench-smoke CI leg) against the committed full-shape snapshot
(``BENCH_kernels.json`` / ``BENCH_fig3.json`` / ``BENCH_decode.json`` /
``BENCH_serve.json``).  Cases are matched by name and paths by name —
smoke runs cover a subset of the snapshot's cases, so only the
intersection is compared, but an empty intersection is itself a failure
(it means the smoke shapes drifted away from the snapshot).

Checked per matched path:
  * ``hbm_bytes`` (and ``topk_cent_bytes`` where present) must not
    exceed the snapshot by more than ``--tol`` (relative);
  * the fresh report's ``agree`` verdict must be true.

Checked per fresh path carrying ``max_abs_diff_vs_xla`` (the decode
schema): an absolute accuracy floor — the diff vs the fp32 XLA oracle
must stay under the ceiling for the case's ``kv_dtype``
(``DIFF_CEILINGS``; quantized pools budget their quantization error,
fp32 budgets pure kernel drift).  Unlike the byte gates this does not
need a matching snapshot case: accuracy is machine-independent and
absolute, so every fresh case is held to it.

Checked per matched case with a ``metrics`` dict (the serve schema):
  * ``prefix_hit_rate`` / ``prefill_tokens_saved`` are floors — pure
    scheduler accounting, so they must not drop below the snapshot by
    more than ``--tol`` (relative);
  * ``speedup`` (prefix-cache on vs off, a within-run ratio, so
    machine-independent in sign) must stay strictly above 1.0.

Checked per fresh case carrying the adaptive-routing metrics (the
``table34_adaptive`` schema), within-run and snapshot-free like the
accuracy ceilings: ``accuracy_adaptive`` must stay within
``ADAPTIVE_ACC_MARGIN`` (1 point) of ``accuracy_static``, and
``bytes_ratio`` (adaptive/static selected-page HBM traffic) must stay
at or under ``ADAPTIVE_BYTES_CEILING`` — the ISSUE's >= 20% reduction
target on the planted-signal config.

Checked per matched case with the open-loop serve metrics
(``sustained_tokens_per_s`` / ``ttft_p99_ms``): wall-derived but gated
against WIDE cross-machine bands rather than ``--tol`` — sustained
tokens/s must stay above snapshot / ``OPEN_LOOP_BAND`` and p99 TTFT
below snapshot × ``OPEN_LOOP_BAND``.  A 3× band never trips on CI-vs-
workstation speed differences but catches the pathologies this exists
for: a dispatch-ahead regression serializing every decode step, or an
admission bug stalling arrivals for whole pipeline depths.

``wall_us`` and the prefix traces' ``tokens_per_s`` are deliberately
ignored across machines: interpret-mode wall time is not TPU-meaningful
(they stay informational in the JSON artifacts).

Exit 0 = clean; exit 1 = regression or disagreement, with a table of
every violation on stderr.
"""
from __future__ import annotations

import argparse
import json
import sys

BYTE_KEYS = ("hbm_bytes", "topk_cent_bytes")
RATE_KEYS = ("prefix_hit_rate", "prefill_tokens_saved")
# absolute per-dtype ceilings on max_abs_diff_vs_xla (decode schema);
# keep in sync with benchmarks.decode_micro.AGREE_TOL
DIFF_CEILINGS = {"fp32": 1e-3, "int8": 5e-2, "fp8": 2e-1}
# adaptive routing (table34_adaptive schema): accuracy may trail static
# by at most 1 point; adaptive/static byte ratio must show the >= 20%
# selected-page reduction the snapshot was accepted with
ADAPTIVE_ACC_MARGIN = 0.01
ADAPTIVE_BYTES_CEILING = 0.80
# open-loop serve traces: wall-derived metrics compared across machines
# only against this wide multiplicative band (see module docstring)
OPEN_LOOP_BAND = 3.0


def _index(report):
    return {c["name"]: c for c in report.get("cases", [])}


def _paths(case):
    # kernels_micro v2 / decode_micro / fig3 use per-path dicts; the
    # seed-era kernels_micro v1 schema had flat per-case fields
    if "paths" in case:
        return case["paths"]
    return {"default": case}


def compare(baseline: dict, new: dict, tol: float):
    """Returns a list of violation strings (empty = clean)."""
    problems = []
    if not new.get("agree", True):
        bad = [c["name"] for c in new.get("cases", [])
               if not c.get("agree", True)]
        problems.append(f"oracle disagreement in fresh run: {bad}")
    base_cases = _index(baseline)
    matched = 0
    for name, case in _index(new).items():
        ceiling = DIFF_CEILINGS.get(case.get("kv_dtype", "fp32"))
        if ceiling is not None:
            for pname, p in _paths(case).items():
                diff = p.get("max_abs_diff_vs_xla")
                if diff is not None and diff > ceiling:
                    problems.append(
                        f"{name}/{pname}: max_abs_diff_vs_xla "
                        f"{diff:.3e} exceeds the "
                        f"{case.get('kv_dtype', 'fp32')} accuracy "
                        f"ceiling {ceiling:.0e}")
        m = case.get("metrics", {})
        if "accuracy_adaptive" in m and "accuracy_static" in m:
            floor = m["accuracy_static"] - ADAPTIVE_ACC_MARGIN
            if m["accuracy_adaptive"] < floor - 1e-9:
                problems.append(
                    f"{name}: accuracy_adaptive "
                    f"{m['accuracy_adaptive']:.3f} below static "
                    f"{m['accuracy_static']:.3f} by more than "
                    f"{ADAPTIVE_ACC_MARGIN:.2f}")
        if "bytes_ratio" in m and m["bytes_ratio"] > ADAPTIVE_BYTES_CEILING:
            problems.append(
                f"{name}: adaptive/static bytes_ratio "
                f"{m['bytes_ratio']:.3f} exceeds the "
                f"{ADAPTIVE_BYTES_CEILING:.2f} ceiling (>= 20% "
                f"selected-page reduction required)")
        base = base_cases.get(name)
        if base is None:
            continue
        base_paths = _paths(base)
        for pname, p in _paths(case).items():
            bp = base_paths.get(pname)
            if bp is None:
                continue
            matched += 1
            for key in BYTE_KEYS:
                if key not in p or key not in bp:
                    continue
                old, cur = bp[key], p[key]
                if cur > old * (1 + tol):
                    problems.append(
                        f"{name}/{pname}: {key} regressed "
                        f"{old:.3e} -> {cur:.3e} "
                        f"(+{(cur / old - 1) * 100:.1f}% > "
                        f"{tol * 100:.0f}%)")
        metrics = case.get("metrics")
        if metrics:
            base_metrics = base.get("metrics", {})
            for key in RATE_KEYS:
                if key not in metrics or key not in base_metrics:
                    continue
                old, cur = base_metrics[key], metrics[key]
                if cur < old * (1 - tol):
                    problems.append(
                        f"{name}: {key} dropped {old:.3f} -> {cur:.3f} "
                        f"(-{(1 - cur / max(old, 1e-9)) * 100:.1f}% > "
                        f"{tol * 100:.0f}%)")
            if "speedup" in metrics and metrics["speedup"] <= 1.0:
                problems.append(
                    f"{name}: prefix-cache speedup {metrics['speedup']:.3f}"
                    f" <= 1.0 (cache-on run must beat cache-off)")
            key = "sustained_tokens_per_s"
            if key in metrics and key in base_metrics:
                floor = base_metrics[key] / OPEN_LOOP_BAND
                if metrics[key] < floor:
                    problems.append(
                        f"{name}: {key} {metrics[key]:.1f} below the "
                        f"snapshot/{OPEN_LOOP_BAND:.0f} floor "
                        f"{floor:.1f} (snapshot "
                        f"{base_metrics[key]:.1f})")
            key = "ttft_p99_ms"
            if "sustained_tokens_per_s" in metrics \
                    and key in metrics and key in base_metrics:
                ceiling = base_metrics[key] * OPEN_LOOP_BAND
                if metrics[key] > ceiling:
                    problems.append(
                        f"{name}: {key} {metrics[key]:.0f}ms above the "
                        f"snapshot×{OPEN_LOOP_BAND:.0f} ceiling "
                        f"{ceiling:.0f}ms (snapshot "
                        f"{base_metrics[key]:.0f}ms)")
    if matched == 0:
        problems.append(
            "no case/path names in common between the fresh run and the "
            "snapshot — smoke shapes drifted; regenerate the snapshot")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", required=True,
                    help="committed snapshot JSON")
    ap.add_argument("--new", required=True, help="freshly generated JSON")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="allowed relative hbm_bytes growth (default 5%%)")
    args = ap.parse_args(argv)
    with open(args.baseline, encoding="utf-8") as f:
        baseline = json.load(f)
    with open(args.new, encoding="utf-8") as f:
        new = json.load(f)
    problems = compare(baseline, new, args.tol)
    if problems:
        print(f"REGRESSION vs {args.baseline}:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"{args.new}: no hbm_bytes regression vs {args.baseline} "
          f"(tol {args.tol * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
