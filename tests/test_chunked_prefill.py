"""Chunked prefill + key-conv paged serving (DESIGN.md §4/§6).

Pins the PR acceptance surface: chunked and one-shot prefill are
bitwise-routing-equivalent (identical pool contents and routed page ids
for every chunk size, including chunk boundaries inside a conv window
and inside a page), key-conv configs are served by the engine with
greedy tokens exactly matching the fixed-batch dense-cache oracle, and
recompute preemption replays exactly under both features.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import MoBAConfig
from repro.core import moba
from repro.core.key_conv import (apply_key_conv, apply_key_conv_with_state,
                                 init_key_conv, key_conv_state_update)
from repro.launch import steps as S
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Scheduler


# ------------------------------------------------------------- unit level
def _chunks(n, size):
    return [(s, min(s + size, n)) for s in range(0, n, size)]


def test_chunked_append_pool_and_routing_match_oneshot():
    """Appending a prompt in chunks of any size leaves the pool (keys,
    values, centroids) bitwise identical to a one-shot append, and every
    chunk's routed page ids equal the same queries' ids under one-shot
    routing.  Chunk sizes cover page-aligned (16), page-straddling (24)
    and sub-page (7) boundaries."""
    rng = np.random.default_rng(0)
    hkv, d, ps, npg = 2, 16, 16, 8
    n, num_pages = 100, 16
    cfg = MoBAConfig(block_size=ps, top_k=3)
    kc = jnp.asarray(rng.normal(size=(1, hkv, n, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, hkv, n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 4, n, d)), jnp.float32)
    table = jnp.asarray(np.arange(npg, dtype=np.int32)[None])

    def fresh():
        return {"pages_k": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
                "pages_v": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
                "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}

    one = PC.paged_append_prefill(fresh(), table, jnp.asarray([n]), kc, vc)
    idx_one, _ = moba.moba_paged_prefill_route(
        q, one["centroids"], table, jnp.asarray([0]), jnp.asarray([n]),
        cfg, page_size=ps)
    for size in (7, 16, 24):
        cache = fresh()
        for s, e in _chunks(n, size):
            cache = PC.paged_append_prefill(
                cache, table, jnp.asarray([e - s]), kc[:, :, s:e],
                vc[:, :, s:e], kv_len=jnp.asarray([s]))
            idx_c, _ = moba.moba_paged_prefill_route(
                q[:, :, s:e], cache["centroids"], table, jnp.asarray([s]),
                jnp.asarray([e - s]), cfg, page_size=ps)
            np.testing.assert_array_equal(
                np.asarray(idx_c), np.asarray(idx_one[:, :, :, s:e]),
                err_msg=f"chunk [{s},{e}) size {size}")
        for leaf in ("pages_k", "pages_v", "centroids"):
            np.testing.assert_array_equal(np.asarray(cache[leaf]),
                                          np.asarray(one[leaf]),
                                          err_msg=f"{leaf} size {size}")


def test_key_conv_state_carrying_bitwise():
    """Conv with carried ring state across chunk boundaries is bitwise
    identical to one-shot conv — including boundaries strictly inside a
    conv window (chunk 7 < width 5 spacing) — and the advanced state
    equals the last W-1 raw keys."""
    rng = np.random.default_rng(1)
    hkv, d, n, width = 2, 16, 50, 5
    w = init_key_conv(jax.random.PRNGKey(0), width, hkv, d)
    k = jnp.asarray(rng.normal(size=(1, hkv, n, d)), jnp.float32)
    one = apply_key_conv(w, k)
    zero = jnp.zeros((1, hkv, width - 1, d), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(apply_key_conv_with_state(w, k, zero)), np.asarray(one))
    for size in (7, 24):
        state = zero
        outs = []
        for s, e in _chunks(n, size):
            outs.append(apply_key_conv_with_state(w, k[:, :, s:e], state))
            state = key_conv_state_update(state, k[:, :, s:e],
                                          jnp.asarray([e - s]))
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(outs, axis=-2)), np.asarray(one),
            err_msg=f"chunk size {size}")
        np.testing.assert_array_equal(np.asarray(state),
                                      np.asarray(k[:, :, n - width + 1:]))
    # ragged rows: a q_len 0 row keeps its state untouched
    st = key_conv_state_update(zero, k[:, :, :8], jnp.asarray([0]))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(zero))


# ----------------------------------------------------------- engine level
def _engine_outs(cfg, params, prompts, gen, **ekw):
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=len(prompts), max_seq_len=64, **ekw))
    reqs = [eng.submit(p, max_new_tokens=gen) for p in prompts]
    eng.run()
    return [r.out for r in reqs], eng


def test_chunked_engine_matches_oneshot_tokens():
    """Greedy streams are identical for the one-shot engine and chunked
    engines at page-aligned and page-straddling chunk sizes (ragged
    prompt lengths, swa+moba interleaved)."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 33, 21)]
    base, _ = _engine_outs(cfg, params, prompts, 10)
    for chunk in (16, 24):
        outs, eng = _engine_outs(cfg, params, prompts, 10,
                                 prefill_chunk=chunk)
        assert outs == base, chunk
        # chunking actually spread prompts over steps
        assert eng.stats["prefill_tokens"] == sum(len(p) for p in prompts)


def test_key_conv_engine_matches_dense_oracle():
    """Acceptance: a key_conv_width > 0 config is admitted and its greedy
    decode tokens match the fixed-batch dense-cache oracle exactly —
    one-shot, chunked (boundary inside a conv window), and on the flash
    backend."""
    cfg = get_smoke_config("moba-340m", key_conv_width=3)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    batch, plen, gen = 3, 33, 8
    prompts = rng.integers(0, cfg.vocab_size, (batch, plen), np.int32)

    caches = T.init_caches(cfg, batch, plen + gen,
                           dtype=jnp.dtype(cfg.dtype))
    prefill_fn = jax.jit(S.make_prefill_step(cfg, backend="reference"),
                         donate_argnums=(2,))
    decode_fn = jax.jit(S.make_decode_step(cfg, backend="reference"),
                        donate_argnums=(2,))
    logits, caches = prefill_fn(params, jnp.asarray(prompts), caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    oracle = [tok]
    for _ in range(gen - 1):
        tok, caches = decode_fn(params, tok, caches)
        oracle.append(tok)
    oracle = np.concatenate([np.asarray(t) for t in oracle], axis=1)

    for ekw in ({}, {"prefill_chunk": 7}, {"prefill_chunk": 16},
                {"attn_backend": "flash"},
                {"attn_backend": "flash", "prefill_chunk": 24}):
        outs, _ = _engine_outs(cfg, params, list(prompts), gen, **ekw)
        np.testing.assert_array_equal(np.asarray(outs, np.int32), oracle,
                                      err_msg=str(ekw))


def test_key_conv_chunked_preemption_replay_exact():
    """Recompute preemption under key-conv + chunked prefill reproduces
    every request's solo greedy stream (ring state rebuilt on replay)."""
    cfg = get_smoke_config("moba-340m", key_conv_width=3)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 35, 30)]
    eng = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=64,
                                           num_pages=8, prefill_chunk=24))
    reqs = [eng.submit(p, max_new_tokens=14) for p in prompts]
    eng.run()
    assert eng.stats["preemptions"] > 0, "test should exercise preemption"
    for p, r in zip(prompts, reqs):
        solo = Engine(cfg, params, EngineConfig(max_seqs=1, max_seq_len=64))
        rs = solo.submit(p, max_new_tokens=14)
        solo.run()
        assert r.out == rs.out, (r.rid, r.out, rs.out)


def test_chunked_scheduler_prefill_phase():
    """Chunked admissions enter the 'prefill' phase: they hold a slot and
    their full page reservation but are excluded from decode batches
    until the engine flips them to 'running'."""
    sched = Scheduler(num_pages=16, page_size=16, max_seqs=2,
                      max_pages_per_seq=4, chunk_tokens=16)
    from repro.serving.scheduler import Request
    r = Request(rid=0, prompt=np.zeros(40, np.int32), max_new_tokens=8)
    sched.submit(r)
    plan = sched.plan_step()
    assert plan.prefills == [r] and plan.decodes == []
    assert r.state == "prefill" and r.slot >= 0
    assert sched.alloc.available == 16 - 3      # ceil(41/16) reserved upfront
    r.cache_len = 16                            # engine ran the first chunk
    plan = sched.plan_step()
    assert plan.prefills == [r] and plan.decodes == []
    r.cache_len = 40
    r.state = "running"                         # engine: final chunk done
    plan = sched.plan_step()
    assert plan.prefills == [] and plan.decodes == [r]
