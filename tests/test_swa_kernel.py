"""Flash SWA Pallas kernel vs the dense-reference sliding window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import dense_attention
from repro.kernels.swa import swa_attention


@pytest.mark.parametrize("n,window,qt,kt,h,hkv,d",
                         [(256, 64, 64, 64, 2, 1, 32),
                          (256, 32, 128, 64, 4, 2, 32),
                          (512, 256, 128, 128, 2, 2, 64),
                          (256, 100, 64, 32, 2, 1, 16),
                          (128, 128, 128, 128, 2, 1, 16)])
def test_swa_kernel_vs_dense_reference(n, window, qt, kt, h, hkv, d):
    ks = jax.random.split(jax.random.PRNGKey(n + window), 3)
    q = jax.random.normal(ks[0], (1, h, n, d), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (1, hkv, n, d), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (1, hkv, n, d), jnp.float32)
    ref = dense_attention(q, k, v, causal=True, window=window)
    out = swa_attention(q.reshape(h, n, d), k.reshape(hkv, n, d),
                        v.reshape(hkv, n, d), window,
                        num_q_heads=h, group=h // hkv,
                        q_tile=qt, k_tile=kt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[0]),
                               rtol=3e-4, atol=3e-4)


def test_swa_kernel_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 256, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 256, 32), jnp.bfloat16)
    out = swa_attention(q, k, v, 64, q_tile=64, k_tile=64)
    ref = dense_attention(q[None], k[None], v[None], causal=True,
                          window=64)[0]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
