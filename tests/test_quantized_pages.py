"""Quantized int8/fp8 K/V page pools: the fp32-oracle accuracy gate.

Covers the PR-8 acceptance surface: quantize-on-append / dequantize-on-
read page pools (``core/quantization.py``, ``serving/paged_cache.py``)
measured against the fp32 paths they shadow —

  * quantize/dequantize round-trip error bounds per dtype, including
    the all-zero page (scale 1.0, exact) and extreme-scale pages;
  * the XLA gather path and both Pallas decode grids on a quantized
    pool vs the *fp32* XLA oracle on the same underlying K/V, within
    per-dtype tolerance — and the Pallas grids vs the quantized XLA
    path at float-rounding distance (dequantization happens in the
    kernel, not in a pre-pass);
  * routing state is bitwise identical across ``kv_dtype`` modes:
    prefill and decode appends produce byte-equal centroids, so
    ``moba_paged_route`` selects identical pages (asserted directly,
    and as needle-block retrieval parity on NIAH batches);
  * engine-level greedy decode on NIAH prompts agrees token-for-token
    across backends at each quantized dtype (xla vs flash on the same
    int8/fp8 pool), the serving analogue of the kernel-level gate;
  * the compiled-mode tiling contract knows byte-wide payloads pack 32
    sublanes (vs 8 for fp32).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import moba
from repro.core import quantization as Q
from repro.data.niah import make_niah_batch
from repro.kernels import moba_decode as MD
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine, EngineConfig

QUANT = ("int8", "fp8")
# end-to-end attention-output tolerance vs the fp32 oracle; keep in
# sync with benchmarks.decode_micro.AGREE_TOL
TOL = {"int8": 5e-2, "fp8": 2e-1}
# Pallas grids vs the quantized XLA path: same math, float-rounding only
KERNEL_TOL = 1e-3


# ------------------------------------------------------- round-trip bounds
@pytest.mark.parametrize("kv_dtype", QUANT)
def test_roundtrip_error_bound(kv_dtype):
    """|dequant(quant(x)) - x| <= scale/2 (int8, round-to-nearest) or
    one e4m3 ulp (fp8) — per (page, head) with amax-derived scales."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, size=(4, 16, 2, 8)), jnp.float32)
    scale = Q.compute_scale(x, (1, 3), kv_dtype)           # (4,2)
    payload = Q.quantize(x, scale[:, None, :, None], kv_dtype)
    assert payload.dtype == Q.PAYLOAD_DTYPES[kv_dtype]
    back = Q.dequantize(payload, scale[:, None, :, None])
    err = np.abs(np.asarray(back) - np.asarray(x))
    s = np.asarray(scale)[:, None, :, None]
    if kv_dtype == "int8":
        assert (err <= s * 0.5 + 1e-7).all()
    else:
        # e4m3: 3 mantissa bits → relative error <= 2^-4 per element
        assert (err <= np.abs(np.asarray(x)) * 2 ** -4 + s).all()


def test_all_zero_page_is_exact():
    """amax == 0 pins the scale to 1.0 so a fresh (or genuinely zero)
    page round-trips exactly and dequantizing init state is a no-op."""
    x = jnp.zeros((2, 8, 2, 4), jnp.float32)
    for kv_dtype in QUANT:
        scale = Q.compute_scale(x, (1, 3), kv_dtype)
        assert (np.asarray(scale) == 1.0).all()
        back = Q.dequantize(Q.quantize(x, scale[:, None, :, None],
                                       kv_dtype),
                            scale[:, None, :, None])
        assert (np.asarray(back) == 0.0).all()


def test_partial_page_scale_ignores_stale_positions():
    """The masked amax (``where=``) must not let garbage beyond the
    valid prefix inflate (or deflate) the scale."""
    x = jnp.concatenate([jnp.full((1, 4, 1, 2), 2.0),
                         jnp.full((1, 4, 1, 2), 1e6)], axis=1)
    wmask = (jnp.arange(8) < 4)[None, :, None, None]
    scale = Q.compute_scale(x, (1, 3), "int8", where=wmask)
    np.testing.assert_allclose(np.asarray(scale), 2.0 / 127.0, rtol=1e-6)


# ------------------------------------------ decode paths vs the fp32 oracle
GEOMETRIES = {
    "ragged": dict(kv_lens=(37, 16, 5, 61), npg=8, num_pages=32),
    # tail page a single token deep, and a one-page sequence
    "tiny-tails": dict(kv_lens=(17, 16, 1), npg=4, num_pages=16),
}


def _build(kv_dtype, geom, cfg):
    """Pool populated by the real prefill-append path (quantization
    happens where serving does it, not in the test)."""
    ps = PC.resolve_page_size(cfg)
    hkv, d, h = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    kv_lens = np.asarray(geom["kv_lens"])
    b, npg = len(kv_lens), geom["npg"]
    rng = np.random.default_rng(7)
    pool = PC.init_page_pool(cfg, geom["num_pages"], ps,
                             with_centroids=True, dtype=jnp.float32,
                             kv_dtype=kv_dtype)
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    free = list(range(geom["num_pages"]))
    rng.shuffle(free)
    table = np.full((b, npg), -1, np.int32)
    for i, n in enumerate(kv_lens):
        for j in range(-(-int(n) // ps)):
            table[i, j] = free.pop()
    table = jnp.asarray(table)
    pool = PC.paged_append_prefill(pool, table, jnp.asarray(kv_lens),
                                   kc, vc)
    q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
    return pool, table, jnp.asarray(kv_lens), q


def _decode_outs(pool, table, kv_lens, q, cfg):
    sk, sv = pool.get("scales_k"), pool.get("scales_v")
    args = (q, pool["pages_k"], pool["pages_v"], pool["centroids"],
            table, kv_lens, cfg.attention.moba)
    return {
        "xla": np.asarray(moba.moba_paged_decode_attention(
            *args, scales_k=sk, scales_v=sv)),
        "pallas_grouped": np.asarray(MD.moba_paged_decode_pallas(
            *args, grid="grouped", scales_k=sk, scales_v=sv)),
        "pallas_flat": np.asarray(MD.moba_paged_decode_pallas(
            *args, grid="flat", scales_k=sk, scales_v=sv)),
    }


@pytest.mark.parametrize("kv_dtype", QUANT)
@pytest.mark.parametrize("geom", GEOMETRIES, ids=GEOMETRIES)
def test_quantized_decode_within_tolerance_of_fp32_oracle(kv_dtype, geom):
    cfg = get_smoke_config("moba-340m")
    g = GEOMETRIES[geom]
    pool0, table, kv_lens, q = _build("fp32", g, cfg)
    oracle = _decode_outs(pool0, table, kv_lens, q, cfg)["xla"]
    pool, *_ = _build(kv_dtype, g, cfg)
    outs = _decode_outs(pool, table, kv_lens, q, cfg)
    tol = TOL[kv_dtype]
    for name, out in outs.items():
        err = np.abs(out - oracle).max()
        assert err <= tol, (name, err)
    # the kernels dequantize in VMEM — they must sit on the quantized
    # XLA path at float-rounding distance, not merely inside ``tol``
    for grid in ("pallas_grouped", "pallas_flat"):
        np.testing.assert_allclose(outs[grid], outs["xla"],
                                   atol=KERNEL_TOL, rtol=KERNEL_TOL)
    # routing state: byte-equal centroids, so identical page selection
    np.testing.assert_array_equal(np.asarray(pool["centroids"]),
                                  np.asarray(pool0["centroids"]))
    idx0, v0 = moba.moba_paged_route(q, pool0["centroids"], table,
                                     kv_lens, cfg.attention.moba)
    idx1, v1 = moba.moba_paged_route(q, pool["centroids"], table,
                                     kv_lens, cfg.attention.moba)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


@pytest.mark.parametrize("kv_dtype", QUANT)
def test_decode_append_requantizes_and_keeps_routing_fp32(kv_dtype):
    """Token-at-a-time decode appends: attention over the requantized
    tail page stays within tolerance of the fp32 pool, and the
    incremental centroid update is bitwise identical (it folds the fp32
    incoming key, never reading the quantized payload)."""
    cfg = get_smoke_config("moba-340m")
    g = dict(kv_lens=(37, 16, 5, 61), npg=8, num_pages=32)
    pool0, table, kv_lens, q = _build("fp32", g, cfg)
    pool, *_ = _build(kv_dtype, g, cfg)
    hkv, d = cfg.num_kv_heads, cfg.resolved_head_dim
    b = len(g["kv_lens"])
    rng = np.random.default_rng(11)
    active = jnp.ones((b,), bool)
    for step in range(3):
        kt = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
        vt = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
        pool0 = PC.paged_append_decode(pool0, table, kv_lens, active,
                                       kt, vt)
        pool = PC.paged_append_decode(pool, table, kv_lens, active,
                                      kt, vt)
        kv_lens = kv_lens + 1
    np.testing.assert_array_equal(np.asarray(pool["centroids"]),
                                  np.asarray(pool0["centroids"]))
    oracle = _decode_outs(pool0, table, kv_lens, q, cfg)["xla"]
    out = _decode_outs(pool, table, kv_lens, q, cfg)["xla"]
    assert np.abs(out - oracle).max() <= TOL[kv_dtype]


@pytest.mark.parametrize("kv_dtype", QUANT)
def test_swa_windowed_decode_dequantizes(kv_dtype):
    """The SWA window-bounded gather reads the same quantized pool
    (moba-340m interleaves swa + moba slots over one pool layout)."""
    cfg = get_smoke_config("moba-340m")
    g = GEOMETRIES["ragged"]
    pool0, table, kv_lens, q = _build("fp32", g, cfg)
    pool, *_ = _build(kv_dtype, g, cfg)
    ref = PC.swa_windowed_decode_attention(q, pool0, table, kv_lens, 31)
    out = PC.swa_windowed_decode_attention(q, pool, table, kv_lens, 31)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() <= TOL[kv_dtype]


# ----------------------------------------------------- NIAH serving gate
def _niah_prompts(cfg, n, seq_len):
    batch = make_niah_batch(np.random.default_rng(13), n, seq_len,
                            cfg.vocab_size)
    return [batch["tokens"][i] for i in range(n)], batch["needle_pos"]


@pytest.mark.parametrize("kv_dtype", QUANT)
def test_niah_greedy_tokens_agree_across_backends(kv_dtype):
    """Engine-level gate: on NIAH prompts, the xla and flash engines
    decode identical greedy streams from the same quantized pool — the
    kernel-side dequantization is numerically interchangeable with the
    XLA gather path all the way through the serving stack."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    prompts, _ = _niah_prompts(cfg, 3, 112)

    def run(backend):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=3, max_seq_len=160, attn_backend=backend,
            kv_dtype=kv_dtype))
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [r.out for r in reqs]

    assert run("xla") == run("flash")


@pytest.mark.parametrize("kv_dtype", QUANT)
def test_niah_needle_routing_parity_with_fp32(kv_dtype):
    """Retrieval-side acceptance: on planted-needle contexts the router
    selects byte-identical pages from a quantized pool — the needle's
    block is found (or missed) exactly as in fp32, so quantization
    cannot change *which* history decode attends to, only its low-order
    bits."""
    cfg = get_smoke_config("moba-340m")
    ps = PC.resolve_page_size(cfg)
    hkv, d, h = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    b, npg = 4, 8
    rng = np.random.default_rng(17)
    kv_lens = np.full((b,), npg * ps, np.int32)
    # keys near zero except a loud needle block per row: routing must
    # pick the needle page identically in both modes
    kc = rng.normal(0, 0.05, size=(b, hkv, npg * ps, d))
    needle_page = rng.integers(0, npg, size=b)
    for i in range(b):
        s = needle_page[i] * ps
        kc[i, :, s:s + ps] = rng.normal(0, 2.0, size=(hkv, ps, d))
    kc = jnp.asarray(kc, jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    table = jnp.asarray(np.arange(b * npg, dtype=np.int32).reshape(b, npg))
    q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)

    def route(kv_dt):
        pool = PC.init_page_pool(cfg, b * npg, ps, with_centroids=True,
                                 dtype=jnp.float32, kv_dtype=kv_dt)
        pool = PC.paged_append_prefill(pool, table, jnp.asarray(kv_lens),
                                       kc, vc)
        idx, valid = moba.moba_paged_route(q, pool["centroids"], table,
                                           jnp.asarray(kv_lens),
                                           cfg.attention.moba)
        return np.asarray(idx), np.asarray(valid)

    i0, v0 = route("fp32")
    i1, v1 = route(kv_dtype)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(v0, v1)


# --------------------------------------------------- tiling + pool layout
def test_tiling_contract_knows_byte_dtypes():
    """Byte-wide payloads pack 32 rows per sublane tile: page_size must
    be a multiple of 32 in compiled mode, vs 8 for fp32."""
    for dt in (jnp.int8, jnp.float8_e4m3fn):
        MD.check_decode_tiling(32, 128, dt)
        MD.check_decode_tiling(64, 256, dt)
        with pytest.raises(ValueError, match="multiple of 32"):
            MD.check_decode_tiling(16, 128, dt)
    MD.check_decode_tiling(16, 128, jnp.float32)  # fp32 grain unchanged


def test_pool_layout_and_fp32_passthrough():
    """Quantized pools carry per-(page, head) fp32 scales init to 1.0;
    kv_dtype='fp32' keeps the pre-quantization layout byte-for-byte
    (no scales leaves, pages at the compute dtype)."""
    cfg = get_smoke_config("moba-340m")
    ps = PC.resolve_page_size(cfg)
    plain = PC.init_page_pool(cfg, 8, ps, with_centroids=True,
                              dtype=jnp.float32)
    via_arg = PC.init_page_pool(cfg, 8, ps, with_centroids=True,
                                dtype=jnp.float32, kv_dtype="fp32")
    assert set(plain) == set(via_arg)
    assert via_arg["pages_k"].dtype == jnp.float32
    qpool = PC.init_page_pool(cfg, 8, ps, with_centroids=True,
                              dtype=jnp.float32, kv_dtype="int8")
    assert qpool["pages_k"].dtype == jnp.int8
    assert qpool["scales_k"].shape == (8, cfg.num_kv_heads)
    assert qpool["scales_v"].dtype == jnp.float32
    assert (np.asarray(qpool["scales_k"]) == 1.0).all()
    assert qpool["centroids"].dtype == jnp.float32
    assert {"scales_k", "scales_v"} <= set(PC.PAGE_LEAVES)
    with pytest.raises(ValueError, match="kv_dtype"):
        PC.init_page_pool(cfg, 8, ps, with_centroids=True,
                          kv_dtype="int4")
