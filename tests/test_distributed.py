"""Distributed-path tests on a small multi-device host mesh.

These run in a subprocess because the device count must be set before jax
initializes (the main test process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap


_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sp_attention_matches_reference():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import MoBAConfig, ShardingConfig
    from repro.core import moba
    from repro.distributed import sharding as shmod
    from repro.distributed.moba_sp import moba_attention_sp
    mesh = shmod.make_compat_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 16))
    k = jax.random.normal(ks[1], (2, 2, 128, 16))
    v = jax.random.normal(ks[2], (2, 2, 128, 16))
    cfg = MoBAConfig(block_size=16, top_k=3)
    with shmod.use_mesh(mesh, ShardingConfig()):
        out = jax.jit(lambda q, k, v: moba_attention_sp(
            q, k, v, cfg, tile=16))(q, k, v)
    ref = moba.moba_attention_reference(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
    print("SP OK")
    """)


def test_cp_decode_matches_reference():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import MoBAConfig, ShardingConfig
    from repro.core import moba
    from repro.distributed import sharding as shmod
    from repro.distributed.moba_sp import moba_decode_cp
    mesh = shmod.make_compat_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 1, 16))
    kc = jax.random.normal(ks[1], (2, 2, 256, 16))
    vc = jax.random.normal(ks[2], (2, 2, 256, 16))
    cfg = MoBAConfig(block_size=16, top_k=3)
    for kv_len in (256, 200, 130):
        with shmod.use_mesh(mesh, ShardingConfig()):
            out = jax.jit(lambda q, kc, vc: moba_decode_cp(
                q, kc, vc, jnp.array(kv_len), cfg))(q, kc, vc)
        ref = moba.moba_decode_attention(q, kc, vc, jnp.array(kv_len), cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)
    print("CP decode OK")
    """)


def test_compressed_psum_all_shards_agree():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim import compression
    from repro.distributed.sharding import make_compat_mesh
    mesh = make_compat_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

    def body(g_local, r_local):
        tree, res = compression.compressed_psum(
            {"g": g_local}, ("data",), {"g": r_local})
        return tree["g"], res["g"]

    out, res = shard_map(body, mesh=mesh,
                         in_specs=(P("data"), P("data")),
                         out_specs=(P("data"), P("data")),
                         check_rep=False)(g, jnp.zeros((8, 64)))
    true_mean = jnp.mean(g, axis=0)
    for shard in np.asarray(out).reshape(8, 1, 64):
        np.testing.assert_allclose(shard[0], np.asarray(true_mean),
                                   atol=0.05)
    print("compressed psum OK")
    """)


def test_pipeline_forward():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward
    from repro.distributed.sharding import make_compat_mesh
    mesh = make_compat_mesh((4,), ("model",))
    # 4 stages of y = tanh(x @ w_s)
    ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))

    def stage(w, x):
        return jnp.tanh(x @ w)

    out = pipeline_forward(stage, ws, x, mesh, axis="model",
                           num_microbatches=4)
    ref = x
    for s in range(4):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("pipeline OK")
    """)


def test_dryrun_single_cell_compiles():
    """The dry-run entry point itself (512 devices) on the smallest cell."""
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--archs", "qwen3-0.6b", "--shapes", "decode_32k",
         "--mesh", "single", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-2000:]}\n" \
                              f"STDERR:\n{r.stderr[-2000:]}"
    assert "lowered + compiled OK" in r.stdout
