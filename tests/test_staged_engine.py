"""Staged engine API (DESIGN.md §9): prefill / insert / generate_step.

Pins the PR acceptance surface: tokens produced by driving the stages
manually — including with dispatch-ahead decode in flight — are exactly
the tokens from the legacy ``run()`` closed loop, across attention
backends, chunked prefill, the prefix cache, preemption replay, and
1/2/4 shards (shard-count invariance runs in a subprocess on the
simulated 8-device mesh, same trick as test_sharded_serving.py).  Also
covers the staged-protocol contracts (stale ``Prefix`` handles, slot
binding, state guards), the open-loop trace driver and its metrics, the
asyncio streaming front end, the unified backend-spec resolver, and the
shaped errors left behind by the ``moba_impl`` removal.
"""
import asyncio
import collections
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import backends as B
from repro.models import transformer as T
from repro.serving import frontend as FE
from repro.serving.engine import (Engine, EngineConfig,
                                  resolve_engine_backend)
from repro.serving.scheduler import ServingError, UnsupportedFeatureError

_ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0, shared_prefix=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, shared_prefix, dtype=np.int32)
    return [np.concatenate([prefix[:min(n, shared_prefix)],
                            rng.integers(0, cfg.vocab_size,
                                         max(n - shared_prefix, 0),
                                         dtype=np.int32)])
            for n in lens]


def _legacy_tokens(cfg, params, ecfg, prompts, gen, eos_id=None):
    """Reference stream: the legacy closed loop, fully synchronous."""
    eng = Engine(cfg, params,
                 dataclasses.replace(ecfg, dispatch_ahead=0))
    reqs = [eng.submit(p, gen, eos_id=eos_id) for p in prompts]
    eng.run()
    return [list(r.out) for r in reqs], eng


def _staged_tokens(cfg, params, ecfg, prompts, gen, eos_id=None):
    """Drive the three stages by hand: admit everything that fits, one
    generate_step per iteration, replay preemption victims first."""
    eng = Engine(cfg, params, ecfg)
    reqs = [eng.make_request(p, gen, eos_id=eos_id) for p in prompts]
    pending = collections.deque(reqs)
    while pending or eng.has_work():
        for r in list(eng.preempted_waiting):
            p = eng.prefill(r)
            if p is None:
                break
            assert eng.insert(p)
        while pending:
            p = eng.prefill(pending[0])
            if p is None:
                break
            assert eng.insert(p)
            pending.popleft()
        eng.generate_step()
    return [list(r.out) for r in reqs], eng


# ------------------------------------------------ staged == legacy matrix
@pytest.mark.parametrize("kw", [
    dict(dispatch_ahead=0),
    dict(attn_backend="xla", prefill_chunk=16, dispatch_ahead=1),
    dict(attn_backend="flash", dispatch_ahead=2),
    dict(prefix_cache=True, prefill_chunk=24, dispatch_ahead=2),
], ids=["ref-sync", "xla-chunked-da1", "flash-da2", "prefix-da2"])
def test_staged_matches_legacy(setup, kw):
    """Acceptance: manual prefill/insert/generate_step driving — with
    the decode pipeline as deep as configured — reproduces the legacy
    run() loop token-for-token on the same EngineConfig."""
    cfg, params = setup
    prompts = _prompts(cfg, (40, 33, 21), seed=1, shared_prefix=24)
    ecfg = EngineConfig(max_seqs=4, max_seq_len=96, **kw)
    want, _ = _legacy_tokens(cfg, params, ecfg, prompts, gen=10)
    got, eng = _staged_tokens(cfg, params, ecfg, prompts, gen=10)
    assert got == want
    da = kw.get("dispatch_ahead", 1)
    if da:   # the pipeline must actually have been in flight
        assert eng.stats["dispatch_depth_peak"] >= da
    else:
        assert eng.stats["dispatch_depth_peak"] <= 1
    if kw.get("prefix_cache"):
        assert eng.stats["prefix_hits"] > 0


def test_staged_eos_overrun_discarded(setup):
    """With dispatch_ahead > 1 the pipeline overruns EOS by up to a
    depth of steps; the overrun tokens must be observed and DISCARDED,
    leaving the same post-EOS cut as the synchronous loop."""
    cfg, params = setup
    prompts = _prompts(cfg, (36,), seed=2)
    ecfg = EngineConfig(max_seqs=2, max_seq_len=96)
    base, _ = _legacy_tokens(cfg, params, ecfg, prompts, gen=12)
    eos = base[0][5]               # a token the stream provably emits
    want, _ = _legacy_tokens(cfg, params, ecfg, prompts, gen=12,
                             eos_id=eos)
    assert len(want[0]) < len(base[0])       # EOS actually cut the run
    got, _ = _staged_tokens(
        cfg, params, dataclasses.replace(ecfg, dispatch_ahead=2),
        prompts, gen=12, eos_id=eos)
    assert got == want


# --------------------------------------------- open-loop trace + replay
def test_open_loop_preemption_replay_exact(setup):
    """Open-loop arrivals on an undersized pool with dispatch_ahead=2:
    preemption drains the pipeline mid-flight, victims replay through
    prefill(), and every request still matches the legacy stream."""
    cfg, params = setup
    prompts = _prompts(cfg, (40, 38, 35, 33, 30), seed=3)
    ecfg = EngineConfig(max_seqs=2, max_seq_len=64, num_pages=6,
                        dispatch_ahead=2)
    want, _ = _legacy_tokens(cfg, params, ecfg, prompts, gen=10)
    eng = Engine(cfg, params, ecfg)
    trace = [FE.TraceItem(prompt=p, max_new_tokens=10, arrival_step=2 * i)
             for i, p in enumerate(prompts)]
    m = FE.time_open_loop(eng, trace)
    reqs = m.pop("_requests")
    assert [list(r.out) for r in reqs] == want
    assert eng.stats["preemptions"] > 0, "trace should exercise replay"
    assert eng.stats["pipeline_drains"] > 0
    assert m["dispatch_depth_peak"] >= 2
    assert m["requests"] == len(prompts)
    assert m["generated_tokens"] == sum(len(t) for t in want)
    assert m["sustained_tokens_per_s"] > 0
    for key in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms",
                "tpot_p99_ms", "decode_steps"):
        assert m[key] >= 0
    assert m["ttft_p99_ms"] >= m["ttft_p50_ms"]


# -------------------------------------------------- protocol contracts
def test_insert_contract_and_stale_handles(setup):
    """Slot binding, state guards, and handle staleness: insert() at the
    wrong slot is an error, prefill() on a running request is an error,
    and a Prefix whose request was preempted before insertion returns
    False (the caller re-prefills via preempted_waiting)."""
    cfg, params = setup
    prompts = _prompts(cfg, (47, 37), seed=4)
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_seq_len=64,
                                           num_pages=6))
    ra = eng.make_request(prompts[0], 12)
    pa = eng.prefill(ra)
    assert pa is not None and pa.slot == ra.slot and ra.state == "prefilled"
    assert pa.token == ra.out[-1]
    with pytest.raises(ServingError, match="slot"):
        eng.insert(pa, slot=pa.slot + 1)
    assert eng.insert(pa) and ra.state == "running"
    with pytest.raises(ServingError, match="state"):
        eng.prefill(ra)                       # running requests don't stage
    # B is prefilled but never inserted; A's page growth on the
    # exhausted pool preempts it (youngest), invalidating the handle
    rb = eng.make_request(prompts[1], 12)
    pb = eng.prefill(rb)
    assert pb is not None and rb.state == "prefilled"
    for _ in range(8):
        eng.generate_step()
        if rb.n_preempt > 0:
            break
    assert rb.n_preempt > 0 and rb.state == "waiting"
    assert eng.insert(pb) is False            # stale: pages were released
    assert rb in eng.preempted_waiting
    eng.run()            # legacy driver interop: re-admits the victim
    assert ra.done and rb.done
    assert eng.generate_step() == []          # idle engine: clean no-op


def test_async_frontend_streams_match_legacy(setup):
    """The asyncio front end streams exactly the legacy tokens, first
    token from prefill and the rest from pipelined generate_steps."""
    cfg, params = setup
    prompts = _prompts(cfg, (40, 33, 21, 28), seed=5)
    ecfg = EngineConfig(max_seqs=4, max_seq_len=96, dispatch_ahead=1)
    want, _ = _legacy_tokens(cfg, params, ecfg, prompts, gen=10)

    async def main():
        eng = Engine(cfg, params, ecfg)
        fe = FE.AsyncFrontend(eng)
        await fe.start()
        reqs = [fe.submit(p, 10) for p in prompts]
        outs = []
        for r in reqs:
            toks = []
            async for t in fe.stream(r):
                toks.append(t)
            outs.append(toks)
        await fe.close()
        return outs, reqs

    outs, reqs = asyncio.run(main())
    assert outs == want
    assert [list(r.out) for r in reqs] == want
    assert all(r.t_first >= r.arrival for r in reqs)


# -------------------------------------------- backend-spec resolution
def test_resolve_backend_spec_unified():
    """One resolver for every surface: empty specs fall back to the
    caller's default, names validate eagerly, engine surfaces wrap the
    registry error in the serving-error hierarchy."""
    assert B.resolve_backend_spec("", default="reference") == "reference"
    assert B.resolve_backend_spec(None, default="sparse") == "sparse"
    assert B.resolve_backend_spec("  xla  ") == "xla"
    assert B.resolve_backend_spec("flash:interpret") == "flash"
    with pytest.raises(B.BackendCapabilityError):
        B.resolve_backend_spec("no-such-backend")
    assert resolve_engine_backend("", "reference") == "reference"
    with pytest.raises(UnsupportedFeatureError) as ei:
        resolve_engine_backend("no-such-backend", "reference")
    assert ei.value.feature == "attn_backend"


def test_moba_impl_removed_everywhere(setup):
    """The moba_impl deprecation is finished: every surface rejects it
    with a shaped error naming the attn_backend replacement."""
    from repro.launch.train import train
    with pytest.raises(ValueError, match="attn_backend='xla'"):
        train("moba-340m", moba_impl="xla")
    with pytest.raises(UnsupportedFeatureError, match="attn_backend"):
        EngineConfig(moba_impl="sparse")


@pytest.mark.parametrize("module", ["repro.launch.train",
                                    "repro.launch.serve"])
def test_moba_impl_cli_flag_rejected(module):
    """Both CLIs fail fast (exit 2) on --moba-impl with a message that
    names the --attn-backend replacement — no silent precedence."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", module, "--moba-impl", "xla"],
        capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 2, (r.stdout, r.stderr)
    err = r.stderr + r.stdout
    assert "--moba-impl was removed" in err
    assert "--attn-backend xla" in err


# ------------------------------------------- shard-count invariance
def test_sharded_staged_shard_count_invariance():
    """Staged driving over 1/2/4 shards (open-loop arrivals, dispatch-
    ahead on) reproduces the single-host legacy stream — subprocess on
    the simulated 8-device mesh, as the device count must be fixed
    before jax initializes."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    code = textwrap.dedent("""
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import frontend as FE
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 33, 21, 28)]
    base = Engine(cfg, params, EngineConfig(max_seqs=4, max_seq_len=64,
                                            dispatch_ahead=0))
    reqs = [base.submit(p, max_new_tokens=8) for p in prompts]
    base.run()
    want = [list(r.out) for r in reqs]
    trace = [FE.TraceItem(prompt=p, max_new_tokens=8, arrival_step=i)
             for i, p in enumerate(prompts)]
    for ns in (1, 2, 4):
        sh = ShardedEngine(cfg, params,
                           EngineConfig(max_seqs=2, max_seq_len=64,
                                        dispatch_ahead=1), n_shards=ns)
        sreqs = FE.run_open_loop(sh, trace)
        assert [list(r.out) for r in sreqs] == want, ns
        assert sh.stats["dispatch_depth_peak"] >= 1, ns
        print("OK", ns, "shards:", sorted({r.shard for r in sreqs}))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert r.stdout.count("OK") == 3
