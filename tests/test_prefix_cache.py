"""Radix-tree prefix cache + copy-on-write page virtualization.

Pins the PR acceptance surface: with ``prefix_cache=True`` the engine's
greedy tokens are exact vs the prefix-cache-off engine (itself pinned to
the dense oracle by the seed suite) while requests that share a prompt
prefix physically share pages — across page-aligned and misaligned share
points (COW), chunked prefill, the flash paged backend, key-conv ring
restore at the share boundary, swap-based preemption replay, and the
recompute fallback when host swap memory is capped.  Host-side pieces
(PagePool refcount guards, PrefixTree insert/match/evict, scheduler
admission edges) run without any model.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefix_tree import PrefixTree
from repro.serving.scheduler import (PagePool, Request, Scheduler,
                                     ServingError)

_ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------- PagePool
def test_pool_double_free_guard():
    """Satellite: release/deref on an already-free page must raise a
    shaped ServingError, not corrupt the free list."""
    pool = PagePool(4)
    pages = [pool.alloc() for _ in range(3)]
    pool.release(pages)
    assert pool.available == 4
    with pytest.raises(ServingError, match="double free"):
        pool.release([pages[0]])
    with pytest.raises(ServingError, match="double free"):
        pool.deref(pages[1])
    assert pool.available == 4          # guard left the free list intact


def test_pool_out_of_range_and_bad_ids():
    pool = PagePool(4)
    with pytest.raises(ServingError, match="out of range"):
        pool.release([7])
    with pytest.raises(ServingError, match="out of range"):
        pool.deref(-1)
    with pytest.raises(ServingError):
        pool.release(["0"])             # non-int id


def test_pool_refcount_sharing():
    """ref/deref: a page freed only when its last reference drops; ref
    on a free page is an error (it isn't anyone's to share)."""
    pool = PagePool(2)
    p = pool.alloc()
    pool.ref(p)
    assert pool.refcount(p) == 2
    assert pool.deref(p) is False       # still held
    assert pool.available == 1
    assert pool.deref(p) is True        # now actually freed
    assert pool.available == 2
    with pytest.raises(ServingError, match="free page"):
        pool.ref(p)


# ----------------------------------------------------------- PrefixTree
def _toks(*vals):
    return np.asarray(vals, np.int32)


def test_tree_insert_match_full_and_partial():
    pool = PagePool(8)
    tree = PrefixTree(page_size=4)
    pages = [pool.alloc() for _ in range(3)]
    tree.insert(_toks(*range(10)), pages, pool)     # 2 full + 1 partial
    assert len(tree) == 3
    assert all(pool.refcount(p) == 2 for p in pages)
    # exact full-page walk + the partial tail page (2 of its tokens)
    got, n = tree.match(_toks(*range(10)))
    assert (got, n) == (pages, 10)
    # shorter query: the partial hop matches only its common prefix
    got, n = tree.match(_toks(*range(9)))
    assert (got, n) == (pages, 9)
    # diverging in the second page: only the first page matches (the
    # diverging child shares no tokens, so no partial hop either)
    q = _toks(0, 1, 2, 3, 99, 98, 97, 96)
    got, n = tree.match(q)
    assert (got, n) == (pages[:1], 4)
    # full_only drops the partial hop (key-conv mode)
    got, n = tree.match(_toks(*range(10)), full_only=True)
    assert (got, n) == (pages[:2], 8)
    # max_tokens caps the walk; the second page becomes a partial hop
    got, n = tree.match(_toks(*range(10)), max_tokens=5)
    assert (got, n) == (pages[:2], 5)
    got, n = tree.match(_toks(*range(10)), max_tokens=5, full_only=True)
    assert (got, n) == (pages[:1], 4)


def test_tree_dedup_and_partial_upgrade():
    """Re-inserting a covered prefix adds no refs; extending a partial
    tail upgrades the node in place, releasing the stale page."""
    pool = PagePool(8)
    tree = PrefixTree(page_size=4)
    a = [pool.alloc(), pool.alloc()]
    tree.insert(_toks(*range(6)), a, pool)          # full + 2-token tail
    tree.insert(_toks(*range(6)), a, pool)          # exact dup: no-op
    assert len(tree) == 2 and pool.refcount(a[0]) == 2
    b = pool.alloc()                                # richer tail page
    tree.insert(_toks(*range(8)), [a[0], b], pool)
    assert pool.refcount(b) == 2
    assert pool.deref(a[1]) is True     # tree dropped its ref on upgrade
    got, n = tree.match(_toks(*range(8)))
    assert (got, n) == ([a[0], b], 8)


def test_tree_lru_evict_respects_refcounts():
    """evict() only reclaims leaves whose pages the tree alone holds,
    oldest-touched first."""
    pool = PagePool(8)
    tree = PrefixTree(page_size=4)
    shared, cold = pool.alloc(), pool.alloc()
    tree.insert(_toks(*range(4)), [shared], pool)   # rc 2: seq + tree
    tree.insert(_toks(*range(100, 104)), [cold], pool)
    pool.deref(cold)                                # tree-only now
    assert tree.evict(pool, 2) == 1     # shared page is pinned
    assert pool.available == 7 and len(tree) == 1
    pool.deref(shared)                              # seq finished
    assert tree.evict(pool, 1) == 1
    assert pool.available == 8 and len(tree) == 0


# ----------------------------------------------- engine token exactness
def _fixture(arch="moba-340m", seed=3, n=6, prefix_len=96, **ckw):
    cfg = get_smoke_config(arch, **ckw)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len, dtype=np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        0, cfg.vocab_size, 5 + i, dtype=np.int32)]) for i in range(n)]
    return cfg, params, prompts


def _serve(cfg, params, prompts, gen=8, **ekw):
    ekw.setdefault("max_seqs", 2)       # staggered admission → later
    ekw.setdefault("max_seq_len", 160)  # requests see cached prefixes
    ekw.setdefault("attn_backend", "reference")
    eng = Engine(cfg, params, EngineConfig(**ekw))
    reqs = [eng.submit(p, max_new_tokens=gen) for p in prompts]
    eng.run()
    return [list(r.out) for r in reqs], eng


@pytest.mark.parametrize("prefix_len", [96, 101])
@pytest.mark.parametrize("kv_dtype", ["fp32", "int8"])
def test_prefix_cache_tokens_exact(prefix_len, kv_dtype):
    """Acceptance: greedy tokens identical with the cache on vs off, for
    page-aligned (96 = 6×16) and misaligned (101 → COW) share points.

    The kv_dtype axis runs the same cases on a quantized pool (via the
    xla backend — reference is fp32-only): shared pages hit the radix
    tree token-exactly at full-page granularity.  Quantized pools never
    share a partial page (writing a suffix into a COW'd tail would
    requantize its shared tokens against a new scale, breaking
    bit-exactness) — like key-conv they match whole pages only, so the
    misaligned case sees zero COW copies instead of four."""
    cfg, params, prompts = _fixture(prefix_len=prefix_len)
    kw = ({} if kv_dtype == "fp32"
          else {"attn_backend": "xla", "kv_dtype": kv_dtype})
    off, _ = _serve(cfg, params, prompts, **kw)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True, **kw)
    assert on == off
    st = eng.stats
    assert st["prefix_hits"] == 4       # all but the first admission wave
    assert st["prefix_hit_tokens"] >= 4 * (prefix_len // 16) * 16
    misaligned = prefix_len % 16 != 0
    assert st["cow_copies"] == (
        4 if misaligned and kv_dtype == "fp32" else 0)


def test_prefix_cache_pages_physically_shared():
    """Admitted-on-hit sequences map the same physical page ids as the
    request that populated the tree — sharing, not copying."""
    cfg, params, prompts = _fixture(prefix_len=64)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=4, max_seq_len=160, prefix_cache=True))
    a = eng.submit(prompts[0], max_new_tokens=4)
    eng.run()
    b = eng.submit(prompts[1], max_new_tokens=4)
    eng.step()
    shared = eng.sched._seq_pages[b.slot][:4]
    assert b.prefix_len == 64
    # a finished, but its prefix pages live on in the tree and now in b
    assert all(eng.sched.alloc.refcount(p) == 2 for p in shared)
    got, n = eng.sched.tree.match(prompts[0][:64], touch=False)
    assert got == shared and n == 64
    eng.run()


def test_prefix_cache_multi_turn_reuse():
    """Turn 2's prompt = turn 1's prompt + its generated tokens: the
    finished request's full cache (partial tail included, inserted at
    finish) accelerates the follow-up."""
    cfg, params, prompts = _fixture(prefix_len=64)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_seq_len=160, prefix_cache=True))
    a = eng.submit(prompts[0], max_new_tokens=8)
    eng.run()
    turn2 = np.concatenate([prompts[0], np.asarray(a.out, np.int32),
                            _toks(1, 2, 3)])
    b = eng.submit(turn2, max_new_tokens=4)
    eng.run()
    # everything up to the last full page of turn 1's cache was reused
    assert eng.stats["prefix_hit_tokens"] >= (len(turn2) // 16 - 1) * 16
    # oracle: fresh engine, same turn-2 prompt
    oracle, _ = _serve(cfg, params, [turn2], gen=4)
    assert list(b.out) == oracle[0]


def test_prefix_cache_chunked_prefill_exact():
    cfg, params, prompts = _fixture()
    off, _ = _serve(cfg, params, prompts, prefill_chunk=32)
    on, eng = _serve(cfg, params, prompts, prefill_chunk=32,
                     prefix_cache=True)
    assert on == off and eng.stats["prefix_hits"] > 0


def test_prefix_cache_flash_backend_exact():
    cfg, params, prompts = _fixture(n=4)
    off, _ = _serve(cfg, params, prompts, attn_backend="flash")
    on, eng = _serve(cfg, params, prompts, attn_backend="flash",
                     prefix_cache=True)
    assert on == off and eng.stats["prefix_hits"] > 0


def test_prefix_cache_key_conv_ring_restore():
    """key_conv archs share full pages only; the suffix prefill's conv
    ring is restored from the boundary page's raw-key tail, so tokens
    stay exact across the share point."""
    cfg, params, prompts = _fixture(key_conv_width=3)
    off, _ = _serve(cfg, params, prompts)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True)
    assert on == off
    st = eng.stats
    assert st["prefix_hits"] > 0
    assert st["prefix_hit_tokens"] % eng.page_size == 0   # full pages


def test_prefix_cache_key_conv_width_guard():
    """Ring state spans width-1 raw keys; restoring it from one page's
    tail needs width-1 <= page_size, else construction must refuse."""
    cfg = get_smoke_config("moba-340m", key_conv_width=18)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ServingError, match="key_conv"):
        Engine(cfg, params, EngineConfig(max_seqs=2, max_seq_len=160,
                                         prefix_cache=True))


@pytest.mark.parametrize("kv_dtype", ["fp32", "int8"])
def test_swap_preemption_replay_exact(kv_dtype):
    """An undersized pool forces preemption mid-stream; victim pages
    swap to host memory and restore on re-admission — tokens exact vs a
    fully-provisioned engine that never preempts.  On the quantized
    axis this only holds because the swap store round-trips payload and
    scales together (``PAGE_LEAVES``) bit-identically: a recompute
    replay would requantize the victim's pages and drift (which is why
    the recompute-equivalence leg below is fp32-only)."""
    cfg, params, prompts = _fixture()
    kw = ({} if kv_dtype == "fp32"
          else {"attn_backend": "xla", "kv_dtype": kv_dtype})
    oracle, _ = _serve(cfg, params, prompts, gen=12, max_seqs=4, **kw)
    on, eng = _serve(cfg, params, prompts, gen=12, max_seqs=4,
                     num_pages=24, prefix_cache=True, **kw)
    assert on == oracle
    assert eng.stats["swap_saves"] > 0
    assert eng.stats["swap_restores"] == eng.stats["swap_saves"]
    if kv_dtype == "fp32":
        # fp32 recompute-replay is bit-equivalent to swap restore
        off, _ = _serve(cfg, params, prompts, gen=12, max_seqs=4,
                        num_pages=24, swap_bytes=0, **kw)
        assert off == oracle


def test_swap_budget_capped_falls_back_to_recompute():
    """swap_bytes too small for one victim: save refused, the victim's
    cache is published to the tree instead and replay recomputes
    (prefix-accelerated) — still exact."""
    cfg, params, prompts = _fixture()
    off, _ = _serve(cfg, params, prompts, gen=12, max_seqs=4,
                    num_pages=24, swap_bytes=0)
    on, eng = _serve(cfg, params, prompts, gen=12, max_seqs=4,
                     num_pages=24, prefix_cache=True, swap_bytes=1)
    assert on == off
    assert eng.stats["swap_fallbacks"] > 0
    assert eng.stats["swap_restores"] == 0


def test_tree_eviction_under_pool_pressure():
    """Unreferenced cold prefixes are evicted LRU to admit new work; the
    engine keeps producing exact tokens while the tree churns."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    # disjoint prompts: every finished request leaves a dead prefix
    prompts = [rng.integers(0, cfg.vocab_size, 80 + i, dtype=np.int32)
               for i in range(6)]
    off, _ = _serve(cfg, params, prompts, gen=6, max_seqs=2, num_pages=16)
    on, eng = _serve(cfg, params, prompts, gen=6, max_seqs=2,
                     num_pages=16, prefix_cache=True)
    assert on == off
    assert eng.stats["tree_evictions"] > 0
    assert len(eng.sched.tree) <= eng.sched.alloc.num_pages


# --------------------------------------------- scheduler admission edges
def _sched(**kw):
    kw.setdefault("num_pages", 8)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seqs", 4)
    kw.setdefault("max_pages_per_seq", 8)
    return Scheduler(**kw)


def _req(rid, n_ctx, gen=4):
    return Request(rid=rid, prompt=np.zeros(n_ctx, np.int32),
                   max_new_tokens=gen)


def test_admission_fifo_head_of_line_blocking():
    """Satellite: a too-big head request blocks the queue (FIFO, no
    reordering) even when a smaller one behind it would fit — and the
    failed attempt leaves the pool untouched."""
    sched = _sched()                    # 8 pages of 4 tokens
    r0 = _req(0, 8)                     # 3 pages (8 tokens + 1 decode)
    sched.submit(r0)
    plan = sched.plan_step(0.0)
    assert plan.prefills == [r0]
    avail = sched.alloc.available       # 5
    big, small = _req(1, 20), _req(2, 4)    # big needs 6 > 5; small fits
    sched.submit(big)
    sched.submit(small)
    r0.cache_len = 8
    plan = sched.plan_step(0.0)
    assert plan.prefills == []          # small blocked behind big
    assert [r.rid for r in sched.waiting] == [1, 2]
    assert sched.alloc.available == avail


def _drive_to_preemption():
    """Two admitted requests exactly exhaust a 7-page pool; decoding b
    across its page boundary forces a preemption where b itself is the
    spare — so the victim search must skip it."""
    sched = _sched(num_pages=7, max_seqs=2)
    a, b = _req(0, 12, gen=12), _req(1, 8, gen=12)   # 4 + 3 pages
    sched.submit(a)
    sched.submit(b)
    plan = sched.plan_step(0.0)
    assert plan.prefills == [a, b] and sched.alloc.available == 0
    a.cache_len, b.cache_len = 12, 8
    while a.state == "running":         # decode b until it needs page 4
        b.out.append(0)
        b.cache_len += 1
        plan = sched.plan_step(0.0)
    return sched, a, b, plan


def test_preemption_skips_youngest_when_it_is_the_spare():
    """The request needing the page never preempts itself, even though
    it is the youngest: the next-youngest (here: the only other) is
    evicted instead, and stays queued when its pages can't be covered."""
    sched, a, b, plan = _drive_to_preemption()
    assert plan.preempted == [a] and a.n_preempt == 1
    assert a.state == "waiting" and a.cache_len == 0
    assert b.state == "running"         # got its page from a's release
    # a (13-token context) needs 4 pages, only 3 free → not re-admitted
    assert sched.waiting[0] is a


def test_finish_on_already_preempted_request():
    """finish() on a request sitting preempted in the waiting queue
    (client cancelled) removes it without touching pages it no longer
    holds, and is idempotent."""
    sched, a, b, _ = _drive_to_preemption()
    free_before = sched.alloc.available
    sched.finish(a)
    assert a.state == "done" and a not in sched.waiting
    assert sched.alloc.available == free_before          # held no pages
    sched.finish(a)                                       # idempotent
    sched.finish(b)
    assert sched.alloc.available == 7


# ------------------------------------------------------- sharded router
def test_sharded_router_prefers_prefix_hit_shard():
    """Router sends a request to the shard whose tree holds its longest
    prefix even when another shard is less loaded; sharded tokens stay
    exact vs prefix-off."""
    code = """
    import numpy as np, jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig
    from repro.serving.sharded import ShardedEngine

    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, 96, dtype=np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        0, cfg.vocab_size, 5 + i, dtype=np.int32)]) for i in range(6)]

    def run(prefix_cache, n_shards):
        eng = ShardedEngine(cfg, params, EngineConfig(
            max_seqs=2, max_seq_len=160, attn_backend="reference",
            prefix_cache=prefix_cache), n_shards=n_shards)
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [list(r.out) for r in reqs], eng.stats

    off, _ = run(False, 2)
    on, st = run(True, 2)
    assert on == off, (on, off)
    assert st["prefix_hits"] > 0, st
    # shard-count invariance: cache-on greedy tokens must not depend
    # on how the fleet is carved up
    for n in (1, 4):
        tok, _ = run(True, n)
        assert tok == off, (n, tok, off)
    print("OK", st["prefix_hits"], st["prefix_hit_tokens"])
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert r.stdout.startswith("OK")
