"""Straggler/heartbeat monitor behaviour."""
from repro.distributed.monitor import HeartbeatMonitor


def make_clock(times):
    it = iter(times)
    return lambda: next(it)


def test_straggler_detected():
    # 10 normal steps of 1s, then a 5s step
    times = [float(i) for i in range(11)] + [16.0]
    flagged = []
    mon = HeartbeatMonitor(threshold=2.0,
                           on_straggler=lambda s, dt, med:
                           flagged.append((s, dt)),
                           clock=make_clock(times))
    for step in range(12):
        mon.beat(step)
    assert flagged and flagged[0][0] == 11 and flagged[0][1] == 6.0
    assert mon.straggler_steps == [11]


def test_no_false_positives_on_uniform_steps():
    times = [i * 1.0 for i in range(30)]
    mon = HeartbeatMonitor(threshold=2.0, clock=make_clock(times))
    for step in range(30):
        mon.beat(step)
    assert mon.straggler_steps == []
    assert mon.median_step_time == 1.0


def test_stall_detection():
    t = {"now": 0.0}
    mon = HeartbeatMonitor(stall_timeout=10.0, clock=lambda: t["now"])
    mon.beat(0)
    t["now"] = 5.0
    assert not mon.is_stalled()
    t["now"] = 20.0
    assert mon.is_stalled()


def test_summary():
    times = [float(i) for i in range(12)]
    mon = HeartbeatMonitor(clock=make_clock(times))
    for step in range(12):
        mon.beat(step)
    s = mon.summary()
    assert s["steps_observed"] == 11 and s["median_s"] == 1.0
