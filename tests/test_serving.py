"""Paged-KV serving engine: decode parity, centroid-cache consistency,
page reuse hygiene, preemption resume, continuous-batching equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import MoBAConfig
from repro.core import moba, routing
from repro.launch.serve import serve, serve_fixed
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import PageAllocator, Request, Scheduler


def _build_paged(rng, kv_lens, *, hkv=2, d=16, ps=16, npg=8, num_pages=32):
    """Scatter dense ragged caches into a paged pool; returns everything."""
    b = len(kv_lens)
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    free = list(range(num_pages))
    rng.shuffle(free)
    table = np.full((b, npg), -1, np.int32)
    for i, n in enumerate(kv_lens):
        for j in range(-(-n // ps)):
            table[i, j] = free.pop()
    table = jnp.asarray(table)
    cache = {"pages_k": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "pages_v": jnp.zeros((num_pages, ps, hkv, d), jnp.float32),
             "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}
    cache = PC.paged_append_prefill(cache, table, jnp.asarray(kv_lens),
                                    kc, vc)
    return cache, table, kc, vc


def test_paged_decode_matches_reference_ragged():
    """Acceptance: paged decode == moba_decode_attention (which the seed
    suite ties to moba_attention_reference) over ragged cache tails."""
    rng = np.random.default_rng(0)
    kv_lens = np.array([37, 16, 5, 128])
    cfg = MoBAConfig(block_size=16, top_k=3)
    cache, table, kc, vc = _build_paged(rng, kv_lens)
    q = jnp.asarray(rng.normal(size=(len(kv_lens), 4, 1, 16)), jnp.float32)
    out = moba.moba_paged_decode_attention(
        q, cache["pages_k"], cache["pages_v"], cache["centroids"], table,
        jnp.asarray(kv_lens), cfg)
    for i, n in enumerate(kv_lens):
        ref = moba.moba_decode_attention(q[i:i + 1], kc[i:i + 1],
                                         vc[i:i + 1], jnp.array(n), cfg)
        np.testing.assert_allclose(np.asarray(out)[i], np.asarray(ref)[0],
                                   atol=1e-3, rtol=1e-3)


def test_paged_append_decode_incremental_centroids():
    """Rank-1 decode updates must equal a from-scratch recompute."""
    rng = np.random.default_rng(1)
    kv_lens = np.array([37, 16])
    ps = 16
    cache, table, kc, vc = _build_paged(rng, kv_lens, npg=4, num_pages=16)
    table = np.asarray(table).copy()
    used = set(table.ravel())
    table[1, 1] = next(p for p in range(16) if p not in used)
    table = jnp.asarray(table)  # fresh page for seq 1 crossing its boundary
    lens = kv_lens.copy()
    for step in range(5):       # walk both tails across page boundaries
        k1 = jnp.asarray(rng.normal(size=(2, 2, 1, 16)), jnp.float32)
        v1 = jnp.asarray(rng.normal(size=(2, 2, 1, 16)), jnp.float32)
        cache = PC.paged_append_decode(cache, table, jnp.asarray(lens),
                                       jnp.asarray([True, True]), k1, v1)
        lens += 1
    kf, _ = PC.paged_gather_kv(cache, table)
    cents = np.asarray(PC.gather_seq_centroids(cache, table))
    for i, n in enumerate(lens):
        ref = routing.block_centroids(kf[i][:, :n], ps)
        np.testing.assert_allclose(cents[i][:, :-(-n // ps)],
                                   np.asarray(ref), atol=1e-5)


def _gather_engine_seq(eng, req):
    """Per-group (keys, centroids) for one running request, densified."""
    row = jnp.asarray(eng.sched.block_table[req.slot][None])
    out = []
    pattern = eng.cfg.layer_pattern
    moba_slots = [f"slot_{i}" for i, k in enumerate(pattern) if k == "moba"]
    flat = jax.tree_util.tree_map(lambda x: x, eng.caches)
    for slot in moba_slots:
        pool = flat[slot]
        n_groups = pool["pages_k"].shape[0]
        for g in range(n_groups):
            cache_g = {k: v[g] for k, v in pool.items()}
            kf, _ = PC.paged_gather_kv(cache_g, row)
            cents = PC.gather_seq_centroids(cache_g, row)
            out.append((np.asarray(kf)[0], np.asarray(cents)[0]))
    return out


def test_engine_centroid_cache_matches_recompute_interleaved():
    """After interleaved prefill/decode (continuous batching), every
    cached page centroid equals block_centroids recomputation."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    eng = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=96,
                                           max_prefill_batch=1))
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                       max_new_tokens=24)
            for n in (33, 17, 21)]
    # max_prefill_batch=1 forces admissions on successive steps, so later
    # prefills interleave with earlier requests' decode.
    for _ in range(6):
        eng.step()
    ps = eng.page_size
    assert all(r.state == "running" for r in reqs)
    # staggered admission → sequences sit at different ragged lengths
    assert len({r.cache_len for r in reqs}) > 1
    for r in reqs:
        n = r.cache_len
        for kf, cents in _gather_engine_seq(eng, r):
            ref = routing.block_centroids(jnp.asarray(kf[:, :n]), ps)
            np.testing.assert_allclose(cents[:, :-(-n // ps)],
                                       np.asarray(ref), atol=1e-4)


def test_page_reuse_after_eviction_no_stale_keys():
    """Pages freed by a finished request are recycled; the new tenant
    must decode exactly as on a fresh pool (no stale K/V or centroids)."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(0, cfg.vocab_size, 40, dtype=np.int32)
    prompt_b = rng.integers(0, cfg.vocab_size, 37, dtype=np.int32)
    # pool of 6 pages (96 tokens): A and B cannot coexist, B reuses A's
    ecfg = EngineConfig(max_seqs=2, max_seq_len=64, num_pages=6,
                        max_prefill_batch=1)
    eng = Engine(cfg, params, ecfg)
    ra = eng.submit(prompt_a, max_new_tokens=12)
    eng.step()
    pages_a = set(p for p in eng.sched.block_table[ra.slot] if p >= 0)
    eng.run()
    assert ra.done
    rb = eng.submit(prompt_b, max_new_tokens=12)
    eng.step()
    pages_b = set(p for p in eng.sched.block_table[rb.slot] if p >= 0)
    assert pages_a & pages_b, "B must recycle A's physical pages"
    eng.run()
    fresh = Engine(cfg, T.init_lm(jax.random.PRNGKey(0), cfg), ecfg)
    rf = fresh.submit(prompt_b, max_new_tokens=12)
    fresh.run()
    assert rb.out == rf.out, (rb.out, rf.out)


def test_paged_engine_matches_fixed_batch():
    """End-to-end: continuous-batching engine reproduces the legacy
    fixed-batch greedy loop token-for-token (ragged prompt length)."""
    for arch in ("moba-340m", "qwen3-0.6b"):
        a = np.asarray(serve(arch, batch=3, prompt_len=33, gen=8,
                             smoke=True))
        b = np.asarray(serve_fixed(arch, batch=3, prompt_len=33, gen=8,
                                   smoke=True))
        np.testing.assert_array_equal(a, b)


def test_preempted_request_resumes_exactly():
    """Recompute-preemption must not change any request's greedy output."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 35, 30)]
    # starved pool: 3 requests × up to 64 tokens on 8 pages of 16
    eng = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=64,
                                           num_pages=8))
    reqs = [eng.submit(p, max_new_tokens=14) for p in prompts]
    eng.run()
    assert eng.stats["preemptions"] > 0, "test should exercise preemption"
    for p, r in zip(prompts, reqs):
        solo = Engine(cfg, params, EngineConfig(max_seqs=1,
                                                max_seq_len=64))
        rs = solo.submit(p, max_new_tokens=14)
        solo.run()
        assert r.out == rs.out, (r.rid, r.out, rs.out)


def test_scheduler_allocator_bookkeeping():
    sched = Scheduler(num_pages=7, page_size=16, max_seqs=2,
                      max_pages_per_seq=4)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.zeros(100, np.int32),
                             max_new_tokens=1))  # exceeds per-seq capacity
    r1 = Request(rid=1, prompt=np.zeros(33, np.int32), max_new_tokens=8)
    r2 = Request(rid=2, prompt=np.zeros(50, np.int32), max_new_tokens=8)
    sched.submit(r1)
    sched.submit(r2)
    plan = sched.plan_step()
    assert [r.rid for r in plan.prefills] == [1, 2]
    assert sched.alloc.available == 7 - 3 - 4  # ceil(34/16)+ceil(51/16)
    r1.cache_len = 34
    r2.cache_len = 51
    plan = sched.plan_step()  # both fit inside already-allocated pages
    assert not plan.preempted
    # r1 crosses a page boundary with an empty pool → the *youngest*
    # running request (r2) is evicted; the oldest survives.
    r1.cache_len = 48
    plan = sched.plan_step()
    assert [r.rid for r in plan.preempted] == [2]
    assert r2.state == "waiting" and r2.slot == -1 and r2.n_preempt == 1
    assert [r.rid for r in plan.decodes] == [1]
    # r2's 4 pages came back, one went to r1's growth
    assert sched.alloc.available == 3


def test_allocator_free_list():
    alloc = PageAllocator(4)
    pages = [alloc.alloc() for _ in range(4)]
    assert sorted(pages) == [0, 1, 2, 3] and alloc.alloc() is None
    alloc.release(pages[:2])
    assert alloc.available == 2
