import os
import sys

# smoke tests / benches see 1 device; only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
