"""End-to-end integration: train loop learns, checkpoint restart resumes
bit-exact, serve decodes, benchmarks run."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import train
from repro.launch.serve import serve


def test_train_loss_decreases(tmp_path):
    _, losses = train("moba-340m", steps=30, batch=4, seq=128, smoke=True,
                      attn_backend="sparse", lr=3e-3)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_checkpoint_restart_bit_exact(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly —
    the fault-tolerance contract."""
    d1 = str(tmp_path / "uninterrupted")
    params_a, losses_a = train("qwen3-0.6b", steps=12, batch=4, seq=64,
                               smoke=True, ckpt_dir=d1, save_interval=6,
                               lr=1e-3, seed=7)
    # interrupted at step 6 (same 12-step schedule), then resumed
    d2 = str(tmp_path / "interrupted")
    train("qwen3-0.6b", steps=12, batch=4, seq=64, smoke=True,
          ckpt_dir=d2, save_interval=6, lr=1e-3, seed=7, stop_at_step=6)
    params_b, losses_b = train("qwen3-0.6b", steps=12, batch=4, seq=64,
                               smoke=True, ckpt_dir=d2, resume="auto",
                               save_interval=6, lr=1e-3, seed=7)
    np.testing.assert_allclose(losses_a[6:], losses_b, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(params_a),
                    jax.tree_util.tree_leaves(params_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoints are logical arrays: restoring onto a different device
    layout (here: plain single-device) must work."""
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_writes=False)
    tree = {"w": jnp.arange(32.0).reshape(4, 8)}
    mgr.save(1, tree)
    restored, _, _ = mgr.restore(jax.eval_shape(lambda: tree),
                                 shardings=None)
    np.testing.assert_allclose(restored["w"], tree["w"])


def test_serve_decode_runs():
    toks = serve("moba-340m", batch=2, prompt_len=32, gen=8, smoke=True)
    assert toks.shape == (2, 8)
    assert bool((toks >= 0).all())


def test_serve_moe_arch():
    toks = serve("qwen2-moe-a2.7b", batch=2, prompt_len=16, gen=4,
                 smoke=True)
    assert toks.shape == (2, 4)


def test_serve_ssm_arch():
    toks = serve("mamba2-780m", batch=2, prompt_len=16, gen=4, smoke=True)
    assert toks.shape == (2, 4)


def test_kernel_impl_in_training_step():
    """One full train step through the Pallas (interpret) kernel path."""
    _, losses = train("moba-340m", steps=2, batch=2, seq=128, smoke=True,
                      attn_backend="kernel", lr=1e-3)
    assert np.isfinite(losses).all()
