"""Reference MoBA semantics vs an independent numpy brute force."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoBAConfig
from repro.core import key_conv, moba


def brute_force_moba(q, k, v, cfg):
    b, h, n, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    bs = cfg.block_size
    nb = n // bs
    out = np.zeros((b, h, n, d))
    for bi in range(b):
        for hi in range(h):
            kv = hi // g
            cents = np.asarray(k[bi, kv]).reshape(nb, bs, d).mean(1)
            for t in range(n):
                s = np.asarray(q[bi, hi, t]) @ cents.T
                own = t // bs
                s[own + 1:] = -np.inf
                s[own] = np.inf
                sel = [j for j in np.argsort(-s, kind="stable")[:cfg.top_k]
                       if s[j] > -np.inf]
                toks = sorted(
                    {x for j in sel
                     for x in range(j * bs, min((j + 1) * bs, t + 1))})
                sc = (np.asarray(q[bi, hi, t])
                      @ np.asarray(k[bi, kv, toks]).T) / np.sqrt(d)
                p = np.exp(sc - sc.max())
                p /= p.sum()
                out[bi, hi, t] = p @ np.asarray(v[bi, kv, toks])
    return out


@pytest.mark.parametrize("bs,k", [(32, 3), (16, 4), (64, 2)])
def test_reference_vs_brute_force(bs, k):
    keys = jax.random.split(jax.random.PRNGKey(bs + k), 3)
    q = jax.random.normal(keys[0], (1, 2, 128, 16))
    kk = jax.random.normal(keys[1], (1, 1, 128, 16))
    v = jax.random.normal(keys[2], (1, 1, 128, 16))
    cfg = MoBAConfig(block_size=bs, top_k=k)
    o = moba.moba_attention_reference(q, kk, v, cfg)
    ob = brute_force_moba(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o), ob, rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_last_row():
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (2, 4, 256, 32))
    k = jax.random.normal(keys[1], (2, 2, 256, 32))
    v = jax.random.normal(keys[2], (2, 2, 256, 32))
    cfg = MoBAConfig(block_size=32, top_k=3)
    o = moba.moba_attention_reference(q, k, v, cfg)
    od = moba.moba_decode_attention(q[:, :, -1:], k, v, jnp.array(256), cfg)
    np.testing.assert_allclose(np.asarray(od[:, :, 0]),
                               np.asarray(o[:, :, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_partial_cache():
    """Decode with kv_len < cache size must ignore invalid positions."""
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (1, 2, 1, 16))
    cache = jax.random.normal(keys[1], (1, 1, 128, 16))
    vcache = jax.random.normal(keys[2], (1, 1, 128, 16))
    cfg = MoBAConfig(block_size=16, top_k=2)
    kv_len = 70
    od = moba.moba_decode_attention(q, cache, vcache, jnp.array(kv_len), cfg)
    # oracle: run prefill reference on the valid prefix
    kp = cache[:, :, :kv_len]
    vp = vcache[:, :, :kv_len]
    # q is at position kv_len-1 (the newest token)
    oref = moba.moba_attention_reference(
        jnp.broadcast_to(q, (1, 2, 1, 16)), kp, vp, cfg,
        q_positions=jnp.array([kv_len - 1]))
    np.testing.assert_allclose(np.asarray(od), np.asarray(oref),
                               rtol=2e-3, atol=2e-3)


def test_bidirectional_moba_no_future_mask():
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(keys[0], (1, 2, 64, 16))
    k = jax.random.normal(keys[1], (1, 2, 64, 16))
    v = jax.random.normal(keys[2], (1, 2, 64, 16))
    cfg = MoBAConfig(block_size=16, top_k=2, causal=False)
    o = moba.moba_attention_reference(q, k, v, cfg)
    assert bool(jnp.isfinite(o).all())
    sel = moba.moba_selection(q, k, cfg)
    # future blocks may be selected in bidirectional mode
    own = jnp.arange(64) // 16
    assert bool((sel > own[None, None, :, None]).any())


def test_key_conv_causality():
    """Perturbing position t must not change conv output before t."""
    w = key_conv.init_key_conv(jax.random.PRNGKey(0), 3, 2, 16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64, 16))
    out1 = key_conv.apply_key_conv(w, k)
    k2 = k.at[:, :, 40].add(10.0)
    out2 = key_conv.apply_key_conv(w, k2)
    np.testing.assert_allclose(np.asarray(out1[:, :, :40]),
                               np.asarray(out2[:, :, :40]), rtol=1e-6)
    assert bool((jnp.abs(out1[:, :, 40:43] - out2[:, :, 40:43]) > 1e-4).any())


def test_key_conv_decode_matches_full():
    w = key_conv.init_key_conv(jax.random.PRNGKey(0), 3, 2, 16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 16))
    full = key_conv.apply_key_conv(w, k)
    state = key_conv.key_conv_state_init(3, 1, 2, 16, dtype=k.dtype)
    outs = []
    for t in range(32):
        o, state = key_conv.apply_key_conv_decode(w, k[:, :, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-5)
