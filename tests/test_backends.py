"""Attention-backend registry + fused paged-decode kernel.

Covers the PR-2 acceptance surface: registry aliases and capability
declarations (every backend must actually run what it declares), the
Pallas decode kernel vs the XLA paged path on ragged batches — through
both the grouped MXU grid and the legacy flat grid, including the
kv_len==0 / non-8-multiple page_size / non-128 head_dim / G==1 edge
geometries — the SWA window-bounded page gather vs densify,
admission-time UnsupportedFeatureError, preemption-replay equality
through the engine on the flash backend, and the interpret/compiled
lowering toggle (env var, registry attribute, ``flash:compiled`` spec,
and the compiled-mode tiling contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import AttentionConfig, MoBAConfig
from repro.core import backends as B
from repro.core import moba
from repro.core.attention import attention_dispatch, dense_attention
from repro.kernels import moba_decode as MD
from repro.kernels import runtime as KR
from repro.kernels.moba_decode import moba_paged_decode_pallas
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine, EngineConfig, engine_supported
from repro.serving.scheduler import ServingError, UnsupportedFeatureError


def _build_paged(rng, kv_lens, *, hkv=2, d=16, ps=16, npg=8, num_pages=32):
    """Scatter dense ragged caches into a paged pool (pool slots that are
    never written keep garbage, as in a recycled production pool)."""
    b = len(kv_lens)
    kc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, npg * ps, d)), jnp.float32)
    free = list(range(num_pages))
    rng.shuffle(free)
    table = np.full((b, npg), -1, np.int32)
    for i, n in enumerate(kv_lens):
        for j in range(-(-n // ps)):
            table[i, j] = free.pop()
    table = jnp.asarray(table)
    cache = {
        "pages_k": jnp.asarray(rng.normal(size=(num_pages, ps, hkv, d)),
                               jnp.float32),
        "pages_v": jnp.asarray(rng.normal(size=(num_pages, ps, hkv, d)),
                               jnp.float32),
        "centroids": jnp.zeros((num_pages, hkv, d), jnp.float32)}
    cache = PC.paged_append_prefill(cache, table, jnp.asarray(kv_lens),
                                    kc, vc)
    return cache, table, kc, vc


# ------------------------------------------------------------------ registry
PAGED_BACKENDS = ("xla", "flash", "sharded")


@pytest.fixture(params=PAGED_BACKENDS)
def paged_backend(request):
    """Every paged-capable non-reference backend.  Engine-level paged
    tests parametrize over this one fixture instead of keeping a copy
    per backend — a new paged backend gets the whole sweep by adding
    its name here."""
    return request.param


_REF = {}


def _reference_fixture():
    """Shared (cfg, params, prompts, reference-engine outputs) for the
    cross-backend sweep — computed once, not once per fixture param."""
    if not _REF:
        cfg = get_smoke_config("moba-340m")
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
                   for n in (40, 33, 21)]
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=3, max_seq_len=64, attn_backend="reference"))
        reqs = [eng.submit(p, max_new_tokens=10) for p in prompts]
        eng.run()
        _REF.update(cfg=cfg, params=params, prompts=prompts,
                    outs=[r.out for r in reqs])
    return _REF


def test_registry_names_and_aliases():
    assert set(B.names()) >= {"reference", "xla", "xla_unrolled", "flash",
                              "sp", "sp_unrolled", "sharded"}
    assert B.get("sparse") is B.get("xla")
    assert B.get("sparse_unrolled") is B.get("xla_unrolled")
    assert B.get("kernel") is B.get("flash")
    assert B.get("pallas") is B.get("flash")
    with pytest.raises(B.BackendCapabilityError):
        B.get("no_such_backend")


def test_capability_query_rejects_and_names_alternatives():
    with pytest.raises(B.BackendCapabilityError, match="reference"):
        B.resolve("sp", kind="moba", phase="decode", cache="paged")
    # sp does resolve for what it declares
    assert B.resolve("sp", kind="moba", phase="prefill").name == "sp"


def test_capability_matrix_backends_run_what_they_declare():
    """Every declared (kind, phase, dense-cache) cell of every local
    backend must execute and agree with the reference backend.  sp/sp_*
    need a mesh (exercised in test_distributed) so only their
    declarations are checked."""
    rng = np.random.default_rng(0)
    mcfg = MoBAConfig(block_size=16, top_k=2)
    cfg = AttentionConfig(kind="moba", window=32, moba=mcfg)
    b, h, hkv, n, d = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float32)
    qd = q[:, :, :1]
    kv_len = jnp.asarray(40)          # dense caches share one length
    ref = B.get("reference")
    for name in ("reference", "xla", "xla_unrolled", "flash", "sharded"):
        be = B.get(name)
        caps = be.capabilities
        for kind in caps.kinds:
            assert "prefill" in caps.phases and "decode" in caps.phases
            out = be.prefill(cfg, kind, q, k, v)
            want = ref.prefill(cfg, kind, q, k, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=2e-3, rtol=2e-3)
            out = be.decode(cfg, kind, qd, k, v, kv_len,
                            q_positions=(kv_len - 1)[None])
            want = ref.decode(cfg, kind, qd, k, v, kv_len,
                              q_positions=(kv_len - 1)[None])
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=2e-3, rtol=2e-3)
    for name in ("sp", "sp_unrolled"):
        assert B.get(name).capabilities.caches == ("dense",)


def test_attention_dispatch_routes_legacy_strings():
    """The former moba_impl strings keep working through the registry."""
    rng = np.random.default_rng(1)
    mcfg = MoBAConfig(block_size=16, top_k=2)
    cfg = AttentionConfig(kind="moba", moba=mcfg)
    q = jnp.asarray(rng.normal(size=(1, 4, 64, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    ref = attention_dispatch(cfg, "moba", q, k, v, backend="reference")
    for legacy in ("sparse", "sparse_unrolled", "kernel"):
        out = attention_dispatch(cfg, "moba", q, k, v, backend=legacy)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)


# ------------------------------------------------------- fused decode kernel
GRIDS = ("grouped", "flat")

GEOMETRIES = {
    # ragged batch incl. a tail page mid-fill and an inactive kv_len=0 row
    "ragged": dict(kv_lens=(37, 16, 5, 128, 0), top_k=3, h=4, hkv=2,
                   d=16, ps=16, npg=8, num_pages=48),
    # page_size not a multiple of the 8-row sublane grain, head_dim not
    # a multiple of the 128 lane count: interpret mode must still agree
    "odd-tiles": dict(kv_lens=(25, 60, 3), top_k=2, h=4, hkv=2,
                      d=24, ps=12, npg=6, num_pages=24),
    # G == 1 (Hkv == H): the grouped grid degenerates to one query row
    # per kv head and must still dedupe/mask correctly
    "g1": dict(kv_lens=(40, 1, 16), top_k=3, h=4, hkv=4,
               d=16, ps=16, npg=4, num_pages=16),
}


def _decode_case(geom):
    rng = np.random.default_rng(2)
    kv_lens = np.array(geom["kv_lens"])
    cfg = MoBAConfig(block_size=geom["ps"], top_k=geom["top_k"])
    cache, table, _, _ = _build_paged(
        rng, kv_lens, hkv=geom["hkv"], d=geom["d"], ps=geom["ps"],
        npg=geom["npg"], num_pages=geom["num_pages"])
    q = jnp.asarray(rng.normal(size=(len(kv_lens), geom["h"], 1,
                                     geom["d"])), jnp.float32)
    args = (q, cache["pages_k"], cache["pages_v"], cache["centroids"],
            table, jnp.asarray(kv_lens), cfg)
    return args, kv_lens, cfg


@pytest.mark.parametrize("grid", GRIDS)
@pytest.mark.parametrize("geom", GEOMETRIES, ids=GEOMETRIES)
def test_pallas_paged_decode_matches_xla(geom, grid):
    """Acceptance: both kernel grids match the XLA paged path within
    1e-3 on ragged batches and the edge geometries above, and emit
    zeros on inactive (kv_len == 0) rows."""
    args, kv_lens, cfg = _decode_case(GEOMETRIES[geom])
    ref = moba.moba_paged_decode_attention(*args)
    out = moba_paged_decode_pallas(*args, grid=grid)
    active = kv_lens > 0
    np.testing.assert_allclose(np.asarray(out)[active],
                               np.asarray(ref)[active],
                               atol=1e-3, rtol=1e-3)
    assert np.all(np.asarray(out)[~active] == 0.0)
    # and under jit (the engine always runs it jitted)
    jout = jax.jit(lambda *a: moba_paged_decode_pallas(
        *a, cfg, grid=grid))(*args[:-1])
    np.testing.assert_allclose(np.asarray(jout)[active],
                               np.asarray(ref)[active],
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("grid", GRIDS)
def test_pallas_paged_decode_short_table(grid):
    """Tables shorter than top_k: selection pads with invalid slots."""
    rng = np.random.default_rng(3)
    kv_lens = np.array([17, 9])
    cfg = MoBAConfig(block_size=16, top_k=8)     # top_k > npg
    cache, table, _, _ = _build_paged(rng, kv_lens, npg=2, num_pages=8)
    q = jnp.asarray(rng.normal(size=(2, 4, 1, 16)), jnp.float32)
    args = (q, cache["pages_k"], cache["pages_v"], cache["centroids"],
            table, jnp.asarray(kv_lens), cfg)
    ref = moba.moba_paged_decode_attention(*args)
    out = moba_paged_decode_pallas(*args, grid=grid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_union_pages_dedupes_and_compacts():
    """The grouped grid's page union: unique valid ids, sorted and
    compacted to the front, padding zeros past n_uniq."""
    idx = jnp.asarray([[[[[3, 1, 3]], [[1, 1, 0]]]]])   # (1,1,2,1,3)
    valid = jnp.asarray([[[[[True, True, False]],
                           [[True, False, True]]]]])
    union, n_uniq = MD.union_pages(idx, valid, npg=8)
    assert union.shape == (1, 1, 6)
    assert int(n_uniq[0, 0]) == 3
    assert union[0, 0, :3].tolist() == [0, 1, 3]        # sorted unique
    assert union[0, 0, 3:].tolist() == [0, 0, 0]        # padding


def test_pallas_decode_unknown_grid_rejected():
    args, _, cfg = _decode_case(GEOMETRIES["ragged"])
    with pytest.raises(ValueError, match="grouped"):
        moba_paged_decode_pallas(*args, grid="typo")


# ------------------------------------------- interpret/compiled toggle
def test_resolve_interpret_precedence(monkeypatch):
    """Explicit arg > env var > auto (non-TPU hosts interpret)."""
    monkeypatch.delenv(KR.ENV_VAR, raising=False)
    assert KR.resolve_interpret(True) is True
    assert KR.resolve_interpret(False) is False
    assert KR.resolve_interpret(None) is True           # CPU test host
    monkeypatch.setenv(KR.ENV_VAR, "0")
    assert KR.resolve_interpret(None) is False
    assert KR.resolve_interpret(True) is True           # arg still wins
    monkeypatch.setenv(KR.ENV_VAR, "compiled")
    assert KR.resolve_interpret(None) is False
    monkeypatch.setenv(KR.ENV_VAR, "interpret")
    assert KR.resolve_interpret(None) is True
    monkeypatch.setenv(KR.ENV_VAR, "maybe")
    with pytest.raises(ValueError, match=KR.ENV_VAR):
        KR.resolve_interpret(None)


def test_compiled_mode_tiling_asserts():
    """The grouped grid's compiled-mode tiling contract: non-conforming
    page_size / head_dim raise a shaped error *before* any pallas_call
    (so a TPU host misconfiguration fails loudly, not inside Mosaic)."""
    with pytest.raises(ValueError, match="multiple of 8"):
        MD.check_decode_tiling(12, 128, jnp.float32)
    with pytest.raises(ValueError, match="multiple of 128"):
        MD.check_decode_tiling(16, 64, jnp.float32)
    with pytest.raises(ValueError, match="multiple of 16"):
        MD.check_decode_tiling(8, 128, jnp.bfloat16)    # bf16 sublane=16
    MD.check_decode_tiling(8, 128, jnp.float32)         # conforming: ok
    # end-to-end: a compiled request on a non-tileable pool raises
    args, _, cfg = _decode_case(GEOMETRIES["odd-tiles"])
    with pytest.raises(ValueError, match="tileable"):
        moba_paged_decode_pallas(*args, interpret=False, grid="grouped")


def test_compiled_mode_moba_tiling_contract():
    """check_moba_tiling / check_topk_tiling (kernels/tiling.py) raise
    shaped errors naming the violating dimension — and the fwd/topk
    wrappers invoke them before any compiled pallas_call."""
    from repro.kernels import tiling as TL
    from repro.kernels.flash_topk import flash_topk
    from repro.kernels.moba_fwd import moba_fwd

    with pytest.raises(ValueError, match="head_dim=64 must be a multiple"):
        TL.check_moba_tiling(128, 128, 128, 64, jnp.float32)
    with pytest.raises(ValueError, match="q_tile=12 must be a multiple"):
        TL.check_moba_tiling(128, 128, 12, 128, jnp.float32)
    with pytest.raises(ValueError, match="kb_tile=8 .*bfloat16 sublane"):
        TL.check_moba_tiling(128, 8, 16, 128, jnp.bfloat16)
    with pytest.raises(ValueError, match="evenly divide block_size"):
        TL.check_moba_tiling(96, 64, 128, 128, jnp.float32)
    # kb_tile == block_size is exempt from the %128 lane rule (small
    # blocks mask-pad); a proper sub-tile is not
    TL.check_moba_tiling(32, 32, 128, 128, jnp.float32)
    with pytest.raises(ValueError, match="kb_tile=64 is the lane dim"):
        TL.check_moba_tiling(256, 64, 128, 128, jnp.float32)
    with pytest.raises(ValueError, match="cent_tile=96 is the lane dim"):
        TL.check_topk_tiling(96, 128, 128, jnp.float32)
    with pytest.raises(ValueError, match="power of two"):
        TL.check_topk_tiling(384, 128, 128, jnp.float32)
    TL.check_topk_tiling(128, 128, 128, jnp.float32)

    # wrapper seam: a compiled request on non-tileable shapes raises the
    # shaped contract error before any pallas_call is attempted
    tb = jnp.zeros((2, 1), jnp.int32)
    qs = jnp.zeros((2, 32, 16), jnp.float32)
    qp = jnp.zeros((2, 32), jnp.int32)
    kb = jnp.zeros((1, 4, 16, 16), jnp.float32)
    with pytest.raises(ValueError, match="moba fwd/bwd"):
        moba_fwd(tb, qs, qp, kb, kb, scale=0.25, block_size=16,
                 n_tokens=64, num_q_heads=2, group=2, q_tile=32,
                 interpret=False)
    q = jnp.zeros((2, 128, 16), jnp.float32)
    cents = jnp.zeros((1, 4, 16), jnp.float32)
    with pytest.raises(ValueError, match="flash_topk"):
        flash_topk(q, cents, 2, 16, group=2, num_q_heads=2,
                   cent_tile=128, interpret=False)


def test_registry_interpret_toggle_reaches_pallas_call(monkeypatch):
    """Acceptance: flipping the registry toggle makes the flash backend
    invoke ``pl.pallas_call`` with interpret=False — asserted by
    monkeypatching pallas_call itself (execution is forced back to
    interpret so the CPU host can still run the kernel)."""
    seen = []
    real = MD.pl.pallas_call

    def spy(*a, **kw):
        seen.append(kw.get("interpret"))
        kw["interpret"] = True
        return real(*a, **kw)

    monkeypatch.setattr(MD.pl, "pallas_call", spy)
    flash = B.get("flash")
    monkeypatch.setattr(flash, "interpret", False)
    args, kv_lens, cfg = _decode_case(GEOMETRIES["ragged"])
    q, pk, pv, cents, table, kvl, _ = args
    cache = {"pages_k": pk, "pages_v": pv, "centroids": cents}
    acfg = AttentionConfig(kind="moba", moba=cfg)
    # flat grid: the ragged test geometry (d=16) is not compiled-
    # tileable, and the toggle wiring is grid-independent
    out = flash.moba_paged_decode(acfg, q, cache, table, kvl,
                                  grid="flat")
    assert seen == [False]
    ref = moba.moba_paged_decode_attention(*args)
    active = kv_lens > 0
    np.testing.assert_allclose(np.asarray(out)[active],
                               np.asarray(ref)[active],
                               atol=1e-3, rtol=1e-3)
    # env var reaches the same seam when the attribute is unset
    monkeypatch.setattr(flash, "interpret", None)
    monkeypatch.setenv(KR.ENV_VAR, "compiled")
    flash.moba_paged_decode(acfg, q, cache, table, kvl, grid="flat")
    assert seen == [False, False]


def test_no_hardcoded_interpret_defaults_in_kernels():
    """Acceptance: kernels/ carries no ``interpret=True`` defaults —
    every wrapper defers to ``kernels.runtime.resolve_interpret``."""
    import pathlib
    import re

    import repro.kernels
    kdir = pathlib.Path(repro.kernels.__file__).parent
    scanned = {p.name for p in sorted(kdir.glob("*.py"))}
    # the scan must actually see every kernel-layer module (guards
    # against the glob silently missing a moved/renamed file)
    for required in ("flash_topk.py", "moba_fwd.py", "moba_bwd.py",
                     "moba_decode.py", "ops.py", "tiling.py",
                     "runtime.py"):
        assert required in scanned, required
    for p in sorted(kdir.glob("*.py")):
        src = p.read_text()
        assert not re.search(r"interpret\s*:\s*bool\s*=\s*True", src), p
        assert not re.search(r"interpret\s*=\s*True", src), p


def test_parse_backend_spec(monkeypatch):
    flash = B.get("flash")
    monkeypatch.setattr(flash, "interpret", None)
    monkeypatch.setattr(flash, "decode_grid", "grouped")
    monkeypatch.setattr(flash, "train_grid", "grouped")
    monkeypatch.setattr(flash, "kb_tile", 0)
    assert B.parse_backend_spec("xla") == "xla"
    assert B.parse_backend_spec("flash:compiled") == "flash"
    assert flash.interpret is False
    assert B.parse_backend_spec("flash:interpret") == "flash"
    assert flash.interpret is True
    assert B.parse_backend_spec("pallas:flat") == "pallas"  # via alias
    assert flash.decode_grid == "flat"
    assert flash.train_grid == "flat"       # grid options set both grids
    assert B.parse_backend_spec("flash:grouped") == "flash"
    assert flash.decode_grid == "grouped"
    assert flash.train_grid == "grouped"
    assert B.parse_backend_spec("flash:kb_tile=64") == "flash"
    assert flash.kb_tile == 64
    # comma-separated multi-option spec
    assert B.parse_backend_spec("flash:compiled,flat,kb_tile=0") == "flash"
    assert flash.interpret is False
    assert flash.train_grid == "flat"
    assert flash.kb_tile == 0
    with pytest.raises(B.BackendCapabilityError, match="option"):
        B.parse_backend_spec("flash:typo")
    with pytest.raises(B.BackendCapabilityError, match="kb_tile"):
        B.parse_backend_spec("flash:kb_tile=big")
    with pytest.raises(B.BackendCapabilityError, match="kb_tile"):
        B.parse_backend_spec("xla:kb_tile=64")
    with pytest.raises(B.BackendCapabilityError, match="toggle"):
        B.parse_backend_spec("xla:compiled")
    with pytest.raises(B.BackendCapabilityError, match="unknown"):
        B.parse_backend_spec("no_such:compiled")


def test_engine_accepts_backend_spec(monkeypatch):
    """EngineConfig.attn_backend takes the 'name:option' spec: the
    option lands on the registry instance and the engine stores the
    bare name; bad specs fail admission as UnsupportedFeatureError."""
    flash = B.get("flash")
    monkeypatch.setattr(flash, "decode_grid", "grouped")
    monkeypatch.setattr(flash, "train_grid", "grouped")
    ref = _reference_fixture()
    eng = Engine(ref["cfg"], ref["params"], EngineConfig(
        max_seqs=3, max_seq_len=64, attn_backend="flash:flat"))
    assert eng.attn_backend == "flash"
    assert flash.decode_grid == "flat"
    reqs = [eng.submit(p, max_new_tokens=10) for p in ref["prompts"]]
    eng.run()
    assert [r.out for r in reqs] == ref["outs"]
    with pytest.raises(UnsupportedFeatureError) as ei:
        Engine(ref["cfg"], ref["params"],
               EngineConfig(attn_backend="flash:typo"))
    assert ei.value.feature == "attn_backend"


def test_swa_windowed_decode_matches_densify():
    """Window-bounded page gather == densify-then-mask, all window/page
    alignments, on a pool whose unused pages hold garbage."""
    rng = np.random.default_rng(4)
    kv_lens = np.array([37, 16, 5, 128, 63])
    cache, table, _, _ = _build_paged(rng, kv_lens, num_pages=48)
    q = jnp.asarray(rng.normal(size=(len(kv_lens), 4, 1, 16)), jnp.float32)
    kvl = jnp.asarray(kv_lens)
    for window in (7, 16, 31, 100, 256):
        out = PC.swa_windowed_decode_attention(q, cache, table, kvl, window)
        kf, vf = PC.paged_gather_kv(cache, table)
        ref = dense_attention(q, kf, vf, causal=True,
                              q_positions=(kvl - 1)[:, None], kv_len=kvl,
                              window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------- engine
def test_engine_backend_agrees_token_for_token(paged_backend):
    """Every paged backend's engine emits the reference engine's greedy
    stream (moba-340m interleaves swa + moba, so this also pins the
    windowed swa decode path against the old densify numerics)."""
    ref = _reference_fixture()
    eng = Engine(ref["cfg"], ref["params"], EngineConfig(
        max_seqs=3, max_seq_len=64, attn_backend=paged_backend))
    reqs = [eng.submit(p, max_new_tokens=10) for p in ref["prompts"]]
    eng.run()
    assert [r.out for r in reqs] == ref["outs"]


def test_engine_preemption_replay_exact(paged_backend):
    """Recompute-preemption through every paged backend reproduces each
    request's solo greedy stream."""
    ref = _reference_fixture()
    cfg, params = ref["cfg"], ref["params"]
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 35, 30)]
    eng = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=64,
                                           num_pages=8,
                                           attn_backend=paged_backend))
    reqs = [eng.submit(p, max_new_tokens=14) for p in prompts]
    eng.run()
    assert eng.stats["preemptions"] > 0, "test should exercise preemption"
    for p, r in zip(prompts, reqs):
        solo = Engine(cfg, params, EngineConfig(max_seqs=1, max_seq_len=64,
                                                attn_backend=paged_backend))
        rs = solo.submit(p, max_new_tokens=14)
        solo.run()
        assert r.out == rs.out, (r.rid, r.out, rs.out)


# ----------------------------------------------------- admission-time errors
def test_key_conv_admitted_and_served():
    """Key-conv configs are engine-servable (per-slot raw-key ring
    buffer, DESIGN.md §4): admission succeeds for every paged backend
    that declares paged key-conv, and the engine decodes greedily."""
    cfg = get_smoke_config("moba-340m", key_conv_width=3)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    assert engine_supported(cfg)
    for name in ("reference",) + PAGED_BACKENDS:
        assert B.resolve(name, kind="moba", phase="decode", cache="paged",
                         key_conv=True).name == name
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_seq_len=64))
    rng = np.random.default_rng(0)
    req = eng.submit(rng.integers(0, cfg.vocab_size, 20, dtype=np.int32),
                     max_new_tokens=4)
    eng.run()
    assert len(req.out) == 4
    # sp stays dense-only, and the old rejection remains structured
    with pytest.raises(UnsupportedFeatureError) as ei:
        Engine(cfg, params, EngineConfig(attn_backend="sp"))
    assert ei.value.feature == "attn_backend"
    assert isinstance(ei.value, ServingError)  # CLI handling unchanged


def test_unpageable_backend_rejected_at_admission():
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(UnsupportedFeatureError) as ei:
        Engine(cfg, params, EngineConfig(attn_backend="sp"))
    assert ei.value.feature == "attn_backend"
    with pytest.raises(UnsupportedFeatureError):
        Engine(cfg, params, EngineConfig(attn_backend="typo"))


def test_engine_config_moba_impl_removed():
    """The long-deprecated ``moba_impl`` alias is gone: constructing an
    EngineConfig with it raises the structured error pointing at
    ``attn_backend`` instead of silently resolving a precedence."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(UnsupportedFeatureError) as ei:
        EngineConfig(moba_impl="xla")
    assert ei.value.feature == "moba_impl"
    assert "attn_backend='xla'" in str(ei.value)
    assert isinstance(ei.value, ServingError)  # CLI handling unchanged
    with pytest.raises(UnsupportedFeatureError):
        EngineConfig(attn_backend="flash", moba_impl="xla")
    # the InitVar leaves no field behind: replace() round-trips without
    # resurrecting the alias, and the default backend is unchanged
    import dataclasses
    ecfg = dataclasses.replace(EngineConfig(attn_backend="flash"),
                               max_seqs=2)
    assert ecfg.attn_backend == "flash" and ecfg.max_seqs == 2
    assert "moba_impl" not in {f.name for f in dataclasses.fields(ecfg)}
    assert Engine(cfg, params, EngineConfig()).attn_backend == "reference"


def test_quantized_kv_gated_at_admission():
    """kv_dtype is a declared capability, not a runtime surprise: a
    backend that never quantizes (reference, sp) rejects int8/fp8 pools
    as a structured UnsupportedFeatureError at admission — before any
    cache is allocated or trace attempted — mirroring the key-conv
    gating above."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    for kv_dtype in ("int8", "fp8"):
        with pytest.raises(UnsupportedFeatureError) as ei:
            Engine(cfg, params, EngineConfig(attn_backend="reference",
                                             kv_dtype=kv_dtype))
        assert ei.value.feature == "attn_backend"
        assert isinstance(ei.value, ServingError)
    # the registry query underneath names the rejection the same way
    with pytest.raises(B.BackendCapabilityError, match="kv_dtype"):
        B.resolve("reference", kind="moba", phase="decode", cache="paged",
                  kv_dtype="int8")
    # quantization-capable backends admit and serve
    for name in PAGED_BACKENDS:
        assert B.resolve(name, kind="moba", phase="decode", cache="paged",
                         kv_dtype="int8").name == name
        assert "int8" in B.get(name).capabilities.kv_dtypes
        assert "fp8" in B.get(name).capabilities.kv_dtypes
    # a typo'd dtype is a config error, not a capability mismatch
    with pytest.raises(ServingError, match="kv_dtype"):
        Engine(cfg, params, EngineConfig(attn_backend="xla",
                                         kv_dtype="int4"))
    # and the generated capability matrix documents the new column
    assert "kv_dtypes" in B.capability_matrix()


def test_capability_query_key_conv():
    assert B.resolve("xla", kind="moba", phase="prefill",
                     key_conv=True).name == "xla"
    nope = B.get("reference").capabilities
    assert nope.supports("moba", "prefill", "dense", key_conv=True)
