"""SNR-guided adaptive per-head routing (DESIGN.md §8).

Three layers of pins:

* **Policy core** — `choose_top_k` inversion properties (own-page
  reservation, monotonicity, the k >= n vacuous-bound guard), policy
  string parsing, profile artifact round-trip + validation, and the
  registry capability gate (`adaptive_topk`).
* **Planted-signal path** — the full calibration pipeline (capture hook
  → `estimate_head_snr` → `choose_top_k`) on a heterogeneous per-head
  workload: strong heads keep the needle while their selected-page
  volume drops >= 20%; weak heads keep the static budget.
* **Engine equivalence** — `route_policy="static"`, a uniform profile
  artifact, and an snr policy that provably resolves to uniform budgets
  are token-exact against the baseline engine across the flash grouped
  grid, the xla flat grid, key-conv, chunked prefill, and quantized
  pools; a *non*-uniform profile decodes identically across backends,
  across 1/2/4 shards (subprocess device mesh, same trick as
  test_sharded_serving.py), and through preempt-swap-restore replay.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import MoBAConfig
from repro.core import adaptive as AD
from repro.core import backends as B
from repro.core import moba as M
from repro.models import transformer as T
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import UnsupportedFeatureError

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ------------------------------------------------------------ policy core
def test_choose_top_k_reserves_own_page_slot():
    # rank 0 is the forced own page; a head with overwhelming SNR still
    # needs one score slot on top of it, so the floor is 2, never 1
    k = AD.choose_top_k(np.array([100.0]), num_blocks=64, k_max=8,
                        pfail=0.01)
    assert k.tolist() == [2]
    # unless the static budget itself is 1
    assert AD.choose_top_k(np.array([100.0]), 64, 1, 0.01).tolist() == [1]


def test_choose_top_k_monotone_and_bounded():
    snrs = np.linspace(0.0, 12.0, 49)
    ks = AD.choose_top_k(snrs, num_blocks=64, k_max=8, pfail=0.01)
    assert ks.min() >= 1 and ks.max() <= 8
    assert all(a >= b for a, b in zip(ks, ks[1:]))   # more SNR, fewer k
    assert ks[0] == 8                                 # no signal: static
    assert ks[-1] == 2                                # strong: own + top1
    # a tighter failure budget never chooses a smaller k
    loose = AD.choose_top_k(snrs, 64, 8, pfail=0.05)
    tight = AD.choose_top_k(snrs, 64, 8, pfail=0.001)
    assert np.all(tight >= loose)


def test_choose_top_k_guards():
    with pytest.raises(ValueError, match="k_max"):
        AD.choose_top_k(np.array([1.0]), 64, 0, 0.01)
    # k >= num_blocks is a vacuous bound, not a ppf domain error
    ks = AD.choose_top_k(np.array([0.0, 50.0]), num_blocks=4, k_max=8,
                         pfail=0.01)
    assert ks.min() >= 1 and ks.max() <= 8


def test_parse_route_policy():
    assert AD.parse_route_policy("static") == ("static", None)
    assert AD.parse_route_policy("") == ("static", None)
    mode, p = AD.parse_route_policy("snr:pfail=0.01")
    assert mode == "snr" and p == pytest.approx(0.01)
    mode, path = AD.parse_route_policy("profile:/tmp/x.json")
    assert mode == "profile" and path == "/tmp/x.json"
    for bad in ("snr", "snr:pfail=0.7", "snr:pfail=-1", "snr:p=0.1",
                "profile:", "greedy"):
        with pytest.raises(ValueError):
            AD.parse_route_policy(bad)


def test_profile_roundtrip_and_validation(tmp_path):
    cfg = get_smoke_config("moba-340m")
    prof = AD.RoutingProfile.uniform(cfg)
    assert prof.is_uniform
    arrs = list(prof.top_k.values())
    arrs[0][:, ::2] = 1                        # make it non-uniform
    assert not prof.is_uniform
    path = str(tmp_path / "prof.json")
    prof.save(path)
    back = AD.RoutingProfile.load(path)
    assert back.k_max == prof.k_max
    assert set(back.top_k) == set(prof.top_k)
    for s in prof.top_k:
        np.testing.assert_array_equal(back.top_k[s], prof.top_k[s])
    # load-time validation: budgets outside [1, k_max] are rejected
    import json
    doc = json.load(open(path))
    doc["top_k"][next(iter(doc["top_k"]))][0][0] = 0
    bad = str(tmp_path / "bad.json")
    json.dump(doc, open(bad, "w"))
    with pytest.raises(ValueError, match="top_k"):
        AD.RoutingProfile.load(bad)


def test_capability_gate_adaptive_topk():
    # paged backends route adaptively; the sequence-parallel fallback
    # keeps static budgets (dense caches, no per-head truncation)
    assert B.resolve("xla", kind="moba", phase="decode",
                     cache="paged", adaptive=True)
    assert B.resolve("flash", kind="moba", phase="decode",
                     cache="paged", adaptive=True)
    assert not B.get("sp").capabilities.adaptive_topk
    with pytest.raises(B.BackendCapabilityError, match="adaptive"):
        B.resolve("sp", kind="moba", phase="prefill", adaptive=True)


def test_engine_rejects_bad_route_policy():
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    for bad in ("snr:pfail=0.9", "profile:/nonexistent.json", "greedy"):
        with pytest.raises(UnsupportedFeatureError):
            Engine(cfg, params, EngineConfig(max_seqs=1, max_seq_len=64,
                                             route_policy=bad))


# --------------------------------------------------- planted-signal path
def _planted_batch(rng, n, d, bs, m_cluster=8, mu_c=0.75):
    """(q (B,H,1,d), keys (B,1,n,d), needle block (B,)): one kv head,
    two query heads — g=0 asks the planted direction, g=1 pure noise."""
    batch = 4
    nb = n // bs
    keys = rng.standard_normal((batch, 1, n, d))
    keys /= np.linalg.norm(keys, axis=-1, keepdims=True)
    u = rng.standard_normal((batch, d))
    u /= np.linalg.norm(u, axis=-1, keepdims=True)
    pos = rng.integers(0, nb - 1, batch)
    for b in range(batch):
        t0 = int(pos[b]) * bs
        for i in range(m_cluster):
            v = keys[b, 0, t0 + i]
            v = v - (v @ u[b]) * u[b]
            v /= np.linalg.norm(v)
            keys[b, 0, t0 + i] = mu_c * u[b] + np.sqrt(
                1 - mu_c ** 2) * v
    q = rng.standard_normal((batch, 2, 1, d))
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    q[:, 0, 0] = u
    return (jnp.asarray(q, jnp.float32), jnp.asarray(keys, jnp.float32),
            pos)


def test_planted_signal_adaptive_cuts_pages_keeps_needle():
    """The full pipeline on a heterogeneous workload: the strong head's
    budget shrinks to own+top1, the noise head keeps k_max, every
    needle stays retrieved, and selected-page volume drops >= 20%."""
    d, bs, nb = 64, 32, 32
    n = nb * bs
    cfg = MoBAConfig(block_size=bs, top_k=8)
    rng = np.random.default_rng(0)
    qpos = jnp.array([n - 1])

    q, keys, _ = _planted_batch(rng, n, d, bs)
    with AD.capture_routing_scores() as caps:
        M.moba_selection(q, keys, cfg, q_positions=qpos)
    assert len(caps) == 1
    scores, qp = caps[0]
    assert np.asarray(scores).shape == (4, 1, 2, 1, nb)
    snr = AD.estimate_head_snr(np.asarray(scores), np.asarray(qp), bs)
    htk = AD.choose_top_k(snr, nb, cfg.top_k, pfail=0.01)
    assert snr[0, 0] > snr[0, 1]           # planted head measures hotter
    assert htk[0, 0] == 2                  # own page + the needle slot
    assert htk[0, 1] == cfg.top_k          # noise head: never adapted

    hits = {"static": 0, "adaptive": 0}
    pages = {"static": 0, "adaptive": 0}
    trials = 0
    for _ in range(4):
        q, keys, pos = _planted_batch(rng, n, d, bs)
        sels = {"static": M.moba_selection(q, keys, cfg,
                                           q_positions=qpos),
                "adaptive": M.moba_selection(
                    q, keys, cfg, q_positions=qpos,
                    head_top_k=jnp.asarray(htk))}
        for path, sel in sels.items():
            sel = np.asarray(sel)
            pages[path] += int((sel < nb).sum())
            hit = (sel[:, 0, 0, :] == pos[:, None]).any(-1)
            hits[path] += int(hit.sum())
        trials += len(pos)
    assert hits["adaptive"] == hits["static"] == trials
    assert pages["adaptive"] <= 0.8 * pages["static"]


def test_estimate_head_snr_short_context_never_adapts():
    # fewer noise blocks than MIN_NOISE_BLOCKS: SNR reports 0, so the
    # inversion keeps the static budget
    s = np.random.default_rng(0).standard_normal((2, 1, 2, 1, 3))
    snr = AD.estimate_head_snr(s, np.array([3 * 16 - 1]), 16)
    assert np.all(snr == 0.0)
    assert np.all(AD.choose_top_k(snr, 3, 4, 0.01) == 4)


# ------------------------------------------------------ engine equivalence
def _outs(cfg, params, prompts, gen, **ekw):
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=len(prompts), max_seq_len=64, **ekw))
    reqs = [eng.submit(p, max_new_tokens=gen) for p in prompts]
    eng.run()
    return [r.out for r in reqs], eng


def test_static_and_uniform_profiles_token_exact(tmp_path):
    """route_policy="static", a saved uniform profile, and an snr policy
    (provably uniform at k_max=2: every budget is min(score+1, 2) = 2)
    decode byte-identical greedy streams across both decode grids,
    chunked prefill, and quantized pools."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 33, 21)]
    upath = str(tmp_path / "uniform.json")
    AD.RoutingProfile.uniform(cfg).save(upath)

    for ekw in ({}, {"attn_backend": "flash"}, {"prefill_chunk": 7},
                {"kv_dtype": "int8", "attn_backend": "xla"},
                {"kv_dtype": "fp8", "attn_backend": "flash"},
                {"attn_backend": "flash", "prefill_chunk": 24}):
        base, _ = _outs(cfg, params, prompts, 8, **ekw)
        for policy in (f"profile:{upath}", "snr:pfail=0.01"):
            outs, eng = _outs(cfg, params, prompts, 8,
                              route_policy=policy, **ekw)
            assert eng.route_profile.is_uniform, (policy, ekw)
            assert outs == base, (policy, ekw)


def test_static_and_uniform_profiles_token_exact_key_conv(tmp_path):
    cfg = get_smoke_config("moba-340m", key_conv_width=3)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 21)]
    upath = str(tmp_path / "uniform.json")
    AD.RoutingProfile.uniform(cfg).save(upath)
    for ekw in ({}, {"attn_backend": "flash", "prefill_chunk": 16}):
        base, _ = _outs(cfg, params, prompts, 8, **ekw)
        outs, _ = _outs(cfg, params, prompts, 8,
                        route_policy=f"profile:{upath}", **ekw)
        assert outs == base, ekw


def _nonuniform_profile(cfg, tmp_path):
    """Half the heads of every moba slot drop to budget 1 (own page
    only) — a real routing change, not a no-op."""
    prof = AD.RoutingProfile.uniform(cfg)
    for arr in prof.top_k.values():
        arr[:, ::2] = 1
    path = str(tmp_path / "nonuniform.json")
    prof.save(path)
    return path


def test_nonuniform_profile_same_tokens_across_backends(tmp_path):
    """A profile that truncates budgets changes the output stream, but
    both decode grids must agree on the changed stream — truncation is
    grid-independent."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 33)]
    path = _nonuniform_profile(cfg, tmp_path)
    static, _ = _outs(cfg, params, prompts, 8)
    xla, ex = _outs(cfg, params, prompts, 8,
                    route_policy=f"profile:{path}")
    flash, _ = _outs(cfg, params, prompts, 8,
                     route_policy=f"profile:{path}",
                     attn_backend="flash")
    chunked, _ = _outs(cfg, params, prompts, 8,
                       route_policy=f"profile:{path}", prefill_chunk=7)
    assert not ex.route_profile.is_uniform
    assert xla == flash == chunked
    assert xla != static        # the truncation actually bit


def test_preemption_replay_under_adaptive_profile(tmp_path):
    """Preempt-swap-restore with a non-uniform profile: the profile is a
    jit-closure constant, so recompute replay must reproduce each
    request's solo greedy stream exactly — same routing decisions before
    and after eviction."""
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (40, 35, 30)]
    path = _nonuniform_profile(cfg, tmp_path)
    policy = f"profile:{path}"
    eng = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=64,
                                           num_pages=8,
                                           route_policy=policy))
    reqs = [eng.submit(p, max_new_tokens=14) for p in prompts]
    eng.run()
    assert eng.stats["preemptions"] > 0, "test should exercise preemption"
    for p, r in zip(prompts, reqs):
        solo = Engine(cfg, params, EngineConfig(max_seqs=1,
                                                max_seq_len=64,
                                                route_policy=policy))
        rs = solo.submit(p, max_new_tokens=14)
        solo.run()
        assert r.out == rs.out, (r.rid, r.out, rs.out)


def test_sharded_profile_shard_count_invariance(tmp_path):
    """One profile replicated across shards: greedy tokens are identical
    on 1, 2, and 4 shards under a non-uniform adaptive profile."""
    path = str(tmp_path / "prof.json")
    _run(f"""
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.core import adaptive as AD
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    prof = AD.RoutingProfile.uniform(cfg)
    for arr in prof.top_k.values():
        arr[:, ::2] = 1
    prof.save({path!r})
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 33, 21, 38)]
    ecfg = dict(max_seqs=2, max_seq_len=64,
                route_policy="profile:" + {path!r})
    one = Engine(cfg, params, EngineConfig(max_seqs=4, max_seq_len=64,
                                           route_policy=ecfg[
                                               "route_policy"]))
    reqs = [one.submit(p, max_new_tokens=8) for p in prompts]
    one.run()
    want = [r.out for r in reqs]
    for shards in (2, 4):
        sh = ShardedEngine(cfg, params, EngineConfig(**ecfg),
                           n_shards=shards)
        sreqs = [sh.submit(p, max_new_tokens=8) for p in prompts]
        sh.run()
        assert [r.out for r in sreqs] == want, shards
        assert not sh.route_profile.is_uniform
        print("OK", shards, "shards")
    """)
