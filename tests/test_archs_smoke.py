"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, shape + finiteness assertions, decode step."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T


def _batch(cfg, b=2, s=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s + 1), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["cross_kv"] = jax.random.normal(
            ks[1], (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            ks[2], (b, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    def loss_fn(p):
        return T.lm_loss(p, batch, cfg)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch

    logits, aux, _ = T.lm_apply(
        params, batch["tokens"][:, :-1], cfg,
        cross_kv=batch.get("cross_kv"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_arch_smoke_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    cross = batch.get("cross_kv")
    if cfg.num_encoder_layers:
        cross = T.apply_encoder(params, batch["src_embeds"], cfg)
    caches = T.init_caches(cfg, 2, 64, dtype=jnp.float32)
    toks = batch["tokens"]
    _, caches = T.prefill(params, toks[:, :16], cfg, caches, cross_kv=cross)
    lg, caches = T.decode_step(params, toks[:, 16:17], cfg, caches,
                               cross_kv=cross)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), arch


def test_full_configs_construct():
    """The exact published configs must construct and validate."""
    expectations = {
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=3072, vocab_size=151936),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936),
        "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=32, d_ff=13440,
                               vocab_size=92416),
        "internlm2-1.8b": dict(num_layers=24, d_model=2048, num_heads=16,
                               num_kv_heads=8, d_ff=8192,
                               vocab_size=92544),
        "mamba2-780m": dict(num_layers=48, d_model=1536, vocab_size=50280),
        "moonshot-v1-16b-a3b": dict(num_layers=48, d_model=2048,
                                    num_heads=16, num_kv_heads=16,
                                    vocab_size=163840),
        "qwen2-moe-a2.7b": dict(num_layers=24, d_model=2048, num_heads=16,
                                num_kv_heads=16, vocab_size=151936),
        "seamless-m4t-medium": dict(num_layers=12, num_encoder_layers=12,
                                    d_model=1024, num_heads=16,
                                    vocab_size=256206),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192,
                                     num_heads=64, num_kv_heads=8,
                                     d_ff=28672, vocab_size=128256),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, vocab_size=32000),
    }
    for arch, exp in expectations.items():
        cfg = configs.get_config(arch)
        for field, val in exp.items():
            assert getattr(cfg, field) == val, (arch, field)
        # layer pattern must tile num_layers exactly
        assert cfg.num_layers % len(cfg.layer_pattern) == 0
    # MoE details
    moon = configs.get_config("moonshot-v1-16b-a3b")
    assert (moon.moe.num_experts, moon.moe.top_k) == (64, 6)
    q2 = configs.get_config("qwen2-moe-a2.7b")
    assert (q2.moe.num_experts, q2.moe.top_k,
            q2.moe.num_shared_experts) == (60, 4, 4)
    z = configs.get_config("zamba2-1.2b")
    assert z.ssm.state_size == 64
    m2 = configs.get_config("mamba2-780m")
    assert m2.ssm.state_size == 128
    # MoBA applied to attention archs, not to mamba2
    assert configs.get_config("qwen3-0.6b").attention.kind == "moba"
    assert "moba" in configs.get_config("qwen3-0.6b").layer_pattern
    assert configs.get_config("mamba2-780m").layer_pattern == ("ssm",)


def test_paper_config_sparsity():
    """Paper §2: (B,k) keeps 7/8 sparsity at N=8192."""
    for bs, k in [(512, 2), (256, 4), (128, 8)]:
        cfg = configs.get_config("moba-340m", block_size=bs, top_k=k)
        nb = 8192 // bs
        assert k / nb == 1 / 8
        assert cfg.attention.moba.block_size == bs
