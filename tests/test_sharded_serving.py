"""Sharded multi-host serving engine (DESIGN.md §7).

Device-level tests run in subprocesses on a simulated 8-device host mesh
(``--xla_force_host_platform_device_count``, same trick as
test_distributed.py) because the device count must be set before jax
initializes.  They pin the PR acceptance surface: greedy tokens from the
sharded engine are exact vs the single-host engine and the dense-cache
oracle — across paged backends, key-conv, chunked prefill on a sharded
pool, and preemption replay — plus the hypothesis stream-invariance
property (1 vs 2 vs 4 shards, permuted router submission order), the
shard-invariant prefill-bucket regression, and the context-parallel
fallback for requests longer than one shard's pool.

Host-side pieces (router policy, bucket purity, registry capability
column) need no devices and run in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# --------------------------------------------------------- host-side pieces
def test_prefill_bucket_is_pure_and_shard_invariant():
    """The bucket is a pure function of (n, page_size): same inputs give
    the same width no matter which engine/shard asks — the invariant the
    sharded engine asserts so jit caches cannot fragment per shard."""
    from repro.serving.engine import prefill_bucket
    for ps in (16, 32):
        base = max(16, ps)
        for n in (1, 7, 16, 17, 40, 64, 100):
            w = prefill_bucket(n, ps)
            assert w >= n and w >= base
            assert w == prefill_bucket(n, ps)         # deterministic
            assert w % base == 0 and (w // base) & (w // base - 1) == 0
    assert prefill_bucket(40, 16) == 64
    assert prefill_bucket(17, 16) == 32


def test_router_least_loaded_deterministic():
    """Router picks the fitting shard with the least page demand, ties
    broken by lowest id; requests too large for any shard return −1."""
    from repro.serving.scheduler import Request, Scheduler
    from repro.serving.sharded import Router

    scheds = [Scheduler(num_pages=8, page_size=16, max_seqs=2,
                        max_pages_per_seq=4) for _ in range(3)]
    router = Router(scheds)
    r = lambda rid, n: Request(rid=rid, prompt=np.zeros(n, np.int32),
                               max_new_tokens=8)
    assert router.pick(r(0, 20)) == 0             # all empty → lowest id
    scheds[0].submit(r(1, 20))                    # queue demand counts
    assert scheds[0].load == 2
    assert router.pick(r(2, 20)) == 1
    scheds[1].submit(r(3, 40))
    scheds[2].submit(r(4, 20))
    assert scheds[1].load == 3 and scheds[2].load == 2
    assert router.pick(r(5, 20)) == 0             # 0 and 2 tie at 2 → 0
    assert router.pick(r(6, 100)) == -1           # fits no shard → CP


def test_sharded_backend_capability_column():
    """The `sharded` backend registers paged-capable; the capability
    column gates the sharded engine's admission query (sp backends issue
    their own collectives and must be rejected)."""
    from repro.core import backends as B
    assert "sharded" in B.names()
    be = B.resolve("sharded", kind="moba", phase="decode", cache="paged",
                   sharded=True)
    assert be.name == "sharded" and be.inner == "xla"
    for name in ("reference", "xla", "flash", "sharded"):
        assert B.get(name).capabilities.sharded, name
    for name in ("sp", "sp_unrolled"):
        assert not B.get(name).capabilities.sharded, name
        with pytest.raises(B.BackendCapabilityError, match="sharded"):
            B.resolve(name, kind="moba", phase="prefill", sharded=True)
    assert "sharded" in B.capability_matrix().splitlines()[0]


def test_sharded_backend_single_host_delegation():
    """`sharded` works on one host too: it is just its inner backend, so
    a plain Engine on attn_backend='sharded' matches the xla engine."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig

    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (33, 21)]
    outs = {}
    for name in ("xla", "sharded"):
        eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_seq_len=64,
                                               attn_backend=name))
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run()
        outs[name] = [r.out for r in reqs]
    assert outs["xla"] == outs["sharded"]


# ------------------------------------------------------- simulated 8-device
def test_sharded_engine_matches_single_host_and_oracle():
    """Acceptance: greedy tokens from the 4-shard engine are exact vs
    the single-host engine AND the legacy dense-cache fixed-batch
    oracle (serve vs serve_fixed wiring included)."""
    _run("""
    import numpy as np
    from repro.launch.serve import serve, serve_fixed
    a = np.asarray(serve("moba-340m", batch=4, prompt_len=33, gen=8,
                         smoke=True, attn_backend="sharded", shards=4))
    b = np.asarray(serve("moba-340m", batch=4, prompt_len=33, gen=8,
                         smoke=True, attn_backend="xla"))
    c = np.asarray(serve_fixed("moba-340m", batch=4, prompt_len=33,
                               gen=8, smoke=True))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
    print("sharded == single-host == oracle")
    """)


def test_sharded_flash_key_conv_chunked_prefill():
    """All paged backends on a sharded pool, including the Pallas flash
    kernel inside the shard_map body, key-conv ring buffers sliced per
    shard, and chunked prefill with conv state carried across chunk
    boundaries — token-exact vs the single-host engine."""
    _run("""
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m", key_conv_width=3)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 33, 21)]
    base = Engine(cfg, params, EngineConfig(max_seqs=3, max_seq_len=64))
    reqs = [base.submit(p, max_new_tokens=8) for p in prompts]
    base.run()
    want = [r.out for r in reqs]
    for kw in ({"attn_backend": "sharded"},
               {"attn_backend": "flash"},
               {"attn_backend": "flash", "prefill_chunk": 24},
               {"attn_backend": "reference", "prefill_chunk": 7}):
        sh = ShardedEngine(cfg, params,
                           EngineConfig(max_seqs=2, max_seq_len=64, **kw),
                           n_shards=2)
        sreqs = [sh.submit(p, max_new_tokens=8) for p in prompts]
        sh.run()
        assert [r.out for r in sreqs] == want, kw
        if kw.get("prefill_chunk"):
            assert sh.stats["prefill_tokens"] == sum(
                len(p) for p in prompts)
        print("OK", kw)
    """)


def test_sharded_preemption_replay_exact():
    """Starved per-shard pools force preemption; recompute replay on the
    owning shard reproduces every request's solo greedy stream."""
    _run("""
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (40, 35, 30, 38)]
    sh = ShardedEngine(cfg, params,
                       EngineConfig(max_seqs=2, max_seq_len=64,
                                    num_pages=6), n_shards=2)
    reqs = [sh.submit(p, max_new_tokens=12) for p in prompts]
    sh.run()
    assert sh.stats["preemptions"] > 0, "test should exercise preemption"
    solo = Engine(cfg, params, EngineConfig(max_seqs=1, max_seq_len=64))
    for p, r in zip(prompts, reqs):
        rs = solo.submit(p, max_new_tokens=12)
        solo.run()
        assert r.out == rs.out, (r.rid, r.out, rs.out)
    print("preemption replay OK:", sh.stats["preemptions"])
    """)


def test_sharded_bucket_invariance_regression():
    """Two shards prefilling ragged prompts in the same step must pad to
    ONE global bucket (the pure-function invariant) — per-shard local
    buckets would compile a decode-step variant per shard and fragment
    the jit cache."""
    _run("""
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, prefill_bucket
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    sh = ShardedEngine(cfg, params,
                       EngineConfig(max_seqs=1, max_seq_len=64),
                       n_shards=2)
    # router spreads these across both shards; locally shard 1 would
    # bucket 18 → 32 while shard 0 needs 64
    r0 = sh.submit(rng.integers(0, cfg.vocab_size, 40, dtype=np.int32), 2)
    r1 = sh.submit(rng.integers(0, cfg.vocab_size, 18, dtype=np.int32), 2)
    assert {r0.shard, r1.shard} == {0, 1}
    sh.step()
    assert sh.prefill_widths == {prefill_bucket(40, sh.page_size)} == {64}
    sh.run()
    assert sh.prefill_widths == {64}      # no per-shard 32-wide compile
    print("bucket invariance OK")
    """)


def test_cp_fallback_long_request_matches_dense_oracle():
    """A request longer than one shard's pool routes to context-parallel
    decode over the mesh (moba_decode_cp on shard-local centroids) and
    its greedy stream matches the dense-cache reference oracle."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.launch import steps as S
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig
    from repro.serving.sharded import ShardedEngine
    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    sh = ShardedEngine(cfg, params,
                       EngineConfig(max_seqs=2, max_seq_len=64),
                       n_shards=4)
    prompt = rng.integers(0, cfg.vocab_size, 100, dtype=np.int32)
    short = rng.integers(0, cfg.vocab_size, 20, dtype=np.int32)
    r = sh.submit(prompt, max_new_tokens=10)      # 110 > 64-token shard
    rs = sh.submit(short, max_new_tokens=4)       # paged path untouched
    assert r.shard == -1 and rs.shard >= 0
    # drive through the public step()/has_work() loop (the Engine API
    # mirror): step() must make progress on the CP queue, not livelock
    steps = 0
    while sh.has_work():
        sh.step()
        steps += 1
        assert steps < 100, "step() livelocked on the CP queue"
    assert sh.stats["cp_requests"] == 1
    assert sh.stats["cp_s"] > 0 and sh.stats["cp_tokens"] == 10
    caches = T.init_caches(cfg, 1, 128, dtype=jnp.dtype(cfg.dtype))
    pf = jax.jit(S.make_prefill_step(cfg, backend="reference"))
    df = jax.jit(S.make_decode_step(cfg, backend="reference"))
    logits, caches = pf(params, jnp.asarray(prompt[None]), caches)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    want = [int(tok[0, 0])]
    for _ in range(9):
        tok, caches = df(params, tok, caches)
        want.append(int(tok[0, 0]))
    assert r.out == want, (r.out, want)
    print("CP fallback == dense oracle")
    """)


def test_cp_decode_awkward_length_falls_back_gracefully():
    """moba_decode_cp must degrade to single-host math, not crash, when
    the cache length cannot shard into whole blocks."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import MoBAConfig, ShardingConfig
    from repro.core import moba
    from repro.distributed import sharding as shmod
    from repro.distributed.moba_sp import moba_decode_cp
    mesh = shmod.make_compat_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 1, 16))
    kc = jax.random.normal(ks[1], (2, 2, 208, 16))   # 208 % (4*16) != 0
    vc = jax.random.normal(ks[2], (2, 2, 208, 16))
    cfg = MoBAConfig(block_size=16, top_k=3)
    with shmod.use_mesh(mesh, ShardingConfig()):
        out = jax.jit(lambda q, kc, vc: moba_decode_cp(
            q, kc, vc, jnp.array(200), cfg))(q, kc, vc)
    ref = moba.moba_decode_attention(q, kc, vc, jnp.array(200), cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
    print("awkward length fallback OK")
    """)


def test_property_stream_invariant_to_shard_count_and_order():
    """Hypothesis: random request streams (lengths, arrival times,
    max_new_tokens) produce identical per-request greedy outputs on 1,
    2 and 4 shards, and under a permuted router submission order."""
    pytest.importorskip("hypothesis")
    _run("""
    import jax, numpy as np
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.sharded import ShardedEngine

    cfg = get_smoke_config("moba-340m")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ecfg = lambda ms: EngineConfig(max_seqs=ms, max_seq_len=64)
    # engines are reused across examples: jit caches stay warm and the
    # scheduler fully drains every run()
    single = Engine(cfg, params, ecfg(6))
    fleets = {s: ShardedEngine(cfg, params, ecfg(3), n_shards=s)
              for s in (1, 2, 4)}
    reorder = ShardedEngine(cfg, params, ecfg(3), n_shards=2)

    req_st = st.tuples(st.integers(4, 40),     # prompt length
                       st.integers(1, 8),      # max_new_tokens
                       st.floats(0, 1))        # arrival time
    stream_st = st.lists(req_st, min_size=2, max_size=5)

    @settings(max_examples=5, deadline=None, derandomize=True,
              database=None,
              suppress_health_check=list(hypothesis.HealthCheck))
    @given(stream=stream_st, data=st.data())
    def check(stream, data):
        rng = np.random.default_rng(hash(tuple(stream)) % 2**32)
        prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
                   for n, _, _ in stream]
        outs = []
        for eng in [single] + list(fleets.values()):
            reqs = [eng.submit(p, max_new_tokens=g, arrival=t)
                    for p, (_, g, t) in zip(prompts, stream)]
            eng.run()
            outs.append([r.out for r in reqs])
        assert all(o == outs[0] for o in outs[1:]), outs
        # permuted submission order changes router assignment, not
        # any request's tokens
        perm = data.draw(st.permutations(range(len(stream))))
        rmap = {i: reorder.submit(prompts[i],
                                  max_new_tokens=stream[i][1],
                                  arrival=stream[i][2]) for i in perm}
        reorder.run()
        assert [rmap[i].out for i in range(len(stream))] == outs[0]

    check()
    print("stream invariance OK")
    """)
