"""Pins for the paper's SNR model (`core/snr.py`), App. A.

`_norm_ppf` is the load-bearing primitive — `required_snr` and the
adaptive `choose_top_k` inversion both stand on it — so it gets direct
coverage here: domain errors, inverse accuracy against the forward
normal CDF, the two rational-approximation branch boundaries (0.02425),
plus monotonicity/edge pins for the formula layer.  Property-based
sweeps run under hypothesis when it is installed; the deterministic
sweeps below cover the same ground either way.
"""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core.snr import (
    _norm_ppf,
    effective_gap,
    p_fail,
    required_snr,
    snr,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:          # container has no hypothesis; sweeps below
    HAVE_HYP = False


def _phi(x: float) -> float:
    """Forward standard-normal CDF (exact, vs the ppf approximation)."""
    return 0.5 * math.erfc(-x / math.sqrt(2.0))


# ------------------------------------------------------------ _norm_ppf
@pytest.mark.parametrize("p", [0.0, 1.0, -0.1, 1.1, 2.0])
def test_norm_ppf_domain(p):
    with pytest.raises(ValueError, match="p in"):
        _norm_ppf(p)


@pytest.mark.parametrize("p", np.concatenate([
    np.linspace(1e-6, 0.02424, 7),          # lower tail branch
    np.linspace(0.02426, 1 - 0.02426, 11),  # central branch
    np.linspace(1 - 0.02424, 1 - 1e-6, 7),  # upper tail branch
]).tolist())
def test_norm_ppf_inverts_phi(p):
    # Acklam quotes |relative error| < 4.5e-4; round-tripping through
    # the exact forward CDF must land back on p to the same order
    assert _phi(_norm_ppf(p)) == pytest.approx(p, rel=2e-3, abs=1e-7)


def test_norm_ppf_branch_boundaries_continuous():
    # the approximation switches branches at plow = 0.02425; both
    # crossings must be continuous to approximation accuracy
    for edge in (0.02425, 1 - 0.02425):
        lo = _norm_ppf(edge - 1e-9)
        hi = _norm_ppf(edge + 1e-9)
        assert abs(hi - lo) < 1e-4


def test_norm_ppf_known_values():
    assert _norm_ppf(0.5) == pytest.approx(0.0, abs=1e-9)
    assert _norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-3)
    assert _norm_ppf(0.025) == pytest.approx(-1.959964, abs=1e-3)
    # symmetry holds in both tail branches
    for p in (1e-4, 0.01, 0.2, 0.4):
        assert _norm_ppf(p) == pytest.approx(-_norm_ppf(1 - p), abs=1e-6)


def test_norm_ppf_monotone():
    ps = np.linspace(1e-5, 1 - 1e-5, 400)
    xs = [_norm_ppf(p) for p in ps]
    assert all(a < b for a, b in zip(xs, xs[1:]))


# -------------------------------------------- required_snr / p_fail
def test_required_snr_is_ppf_inverse():
    # required_snr(n, k) is definitionally Φ⁻¹(1 − k/n): retrieval at
    # that SNR fails a single pairwise comparison with probability k/n
    for n, k in [(64, 1), (64, 8), (128, 4), (1024, 16), (16, 8)]:
        need = required_snr(n, k)
        assert need == pytest.approx(_norm_ppf(1.0 - k / n), abs=0)
        assert _phi(-need) == pytest.approx(k / n, rel=2e-3)


def test_required_snr_roundtrip_through_p_fail():
    # p_fail(d, B, Δμ_eff) = Φ(−SNR); feeding the required SNR back
    # through the failure model recovers k/n
    d, bs = 64, 32
    for n, k in [(64, 2), (256, 8)]:
        need = required_snr(n, k)
        # invert snr() for the Δμ_eff that realizes exactly `need`
        gap = need / math.sqrt(d / (2.0 * bs))
        assert p_fail(d, bs, gap) == pytest.approx(k / n, rel=2e-3)


def test_required_snr_monotone_in_k_and_n():
    # easier target (larger k) → smaller required SNR; more competitors
    # (larger n at fixed k) → larger required SNR
    needs_k = [required_snr(64, k) for k in (1, 2, 4, 8, 16, 32)]
    assert all(a > b for a, b in zip(needs_k, needs_k[1:]))
    needs_n = [required_snr(n, 4) for n in (8, 16, 64, 256, 1024)]
    assert all(a < b for a, b in zip(needs_n, needs_n[1:]))


def test_required_snr_k_equals_n_rejected():
    # k == n gives q = 0, outside the ppf domain — callers must guard
    # (choose_top_k treats k >= n as a vacuous bound)
    with pytest.raises(ValueError):
        required_snr(64, 64)


# --------------------------------------------------- snr / effective_gap
def test_snr_monotone_in_d_and_block_size():
    # SNR = Δμ_eff·sqrt(d/2B): grows with head dim, shrinks with block
    by_d = [snr(d, 64, 1.0) for d in (16, 32, 64, 128, 256)]
    assert all(a < b for a, b in zip(by_d, by_d[1:]))
    by_b = [snr(64, bs, 1.0) for bs in (16, 32, 64, 128, 256)]
    assert all(a > b for a, b in zip(by_b, by_b[1:]))
    # exact scaling pins, paper Eq. (3)
    assert snr(64, 32, 2.0) == pytest.approx(2.0 * math.sqrt(1.0))
    assert snr(256, 32, 1.0) == pytest.approx(4.0 * snr(16, 32, 1.0))


def test_p_fail_monotone_in_gap():
    fails = [p_fail(64, 32, g) for g in (0.0, 0.5, 1.0, 2.0, 4.0)]
    assert fails[0] == pytest.approx(0.5)      # no signal: coin flip
    assert all(a > b for a, b in zip(fails, fails[1:]))
    assert fails[-1] < 1e-4


def test_effective_gap_edge_cases():
    # m=1: no clustering term regardless of the cluster affinities
    assert effective_gap(0.7, m=1, mu_cluster=0.9, mu_noise=0.1) == 0.7
    # mu_cluster == mu_noise: clustering adds nothing for any m
    assert effective_gap(0.7, m=8, mu_cluster=0.3, mu_noise=0.3) == 0.7
    # the paper's linear-in-m growth, Eq. after (2)
    assert effective_gap(0.5, m=4, mu_cluster=0.4, mu_noise=0.1) == (
        pytest.approx(0.5 + 3 * 0.3))
    # anti-clustered keys (mu_cluster < mu_noise) reduce the gap
    assert effective_gap(0.5, m=4, mu_cluster=0.0,
                         mu_noise=0.2) < 0.5


if HAVE_HYP:
    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=1e-6, max_value=1 - 1e-6))
    def test_hyp_norm_ppf_inverts_phi(p):
        assert _phi(_norm_ppf(p)) == pytest.approx(p, rel=2e-3,
                                                   abs=1e-7)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(min_value=2, max_value=4096),
           st.data())
    def test_hyp_required_snr_roundtrip(n, data):
        k = data.draw(st.integers(min_value=1, max_value=n - 1))
        assert _phi(-required_snr(n, k)) == pytest.approx(
            k / n, rel=2e-3, abs=1e-7)

    @settings(max_examples=100, deadline=None)
    @given(st.floats(min_value=0.0, max_value=4.0),
           st.integers(min_value=1, max_value=64),
           st.floats(min_value=-1.0, max_value=1.0),
           st.floats(min_value=-1.0, max_value=1.0))
    def test_hyp_effective_gap_linear(delta, m, mu_c, mu_n):
        gap = effective_gap(delta, m=m, mu_cluster=mu_c, mu_noise=mu_n)
        assert gap == pytest.approx(delta + (m - 1) * (mu_c - mu_n))
