"""Hypothesis property-based tests on system invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.base import MoBAConfig
from repro.core import moba, routing

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(n_exp=st.integers(4, 6), bs_exp=st.integers(2, 4),
       k=st.integers(1, 4), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_selection_invariants(n_exp, bs_exp, k, seed):
    """For any (N, B, k): own block selected; ≤k blocks; causal; sentinel
    only when fewer than k valid blocks exist."""
    n, bs = 2 ** n_exp * 8, 2 ** bs_exp * 4
    n = max(n, bs * 2)
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(keys[0], (1, 2, n, 8))
    kk = jax.random.normal(keys[1], (1, 1, n, 8))
    cfg = MoBAConfig(block_size=bs, top_k=k)
    sel = np.asarray(moba.moba_selection(q, kk, cfg))[0]
    nb = -(-n // bs)
    own = np.arange(n) // bs
    for h in range(sel.shape[0]):
        for t in range(n):
            s = sel[h, t]
            valid = s[s < nb]
            assert len(set(valid.tolist())) == len(valid)  # no dup blocks
            assert (valid <= own[t]).all()                 # causal
            assert own[t] in valid                         # own forced
            expect_valid = min(k, own[t] + 1)
            assert len(valid) == expect_valid
            assert (s[expect_valid:] == nb).all()          # sentinels last?
            # (sentinels occupy the lowest-score slots by construction)


@given(nq=st.sampled_from([32, 64, 128]), k=st.integers(1, 4),
       tile=st.sampled_from([8, 16, 32]), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_varlen_layout_invariants(nq, k, tile, seed):
    """Layout is a bijection pairs↔slots; tiles homogeneous; capacity
    static."""
    nb = 8
    rng = np.random.default_rng(seed)
    # random selections incl. sentinels
    sel = rng.integers(0, nb + 1, size=(nq, k)).astype(np.int32)
    lay = routing.build_varlen_layout(jnp.asarray(sel), nq, nb, tile)
    qi, sb = np.asarray(lay.q_index), np.asarray(lay.slot_block)
    tb, ps = np.asarray(lay.tile_block), np.asarray(lay.pair_slot)
    assert len(qi) == routing.layout_capacity(nq, k, nb, tile)
    # bijection for real pairs
    real = [(t, int(sel[t, i])) for t in range(nq) for i in range(k)
            if sel[t, i] < nb]
    slots = {(int(qi[s]), int(sb[s])) for s in range(len(qi)) if qi[s] >= 0}
    assert len(slots) >= len(set(real)) or slots == set(real)
    assert slots == set(real)
    # pair_slot consistency
    for t in range(nq):
        for i in range(k):
            if sel[t, i] < nb:
                s = ps[t, i]
                assert qi[s] == t and sb[s] == sel[t, i]
    # tile homogeneity
    for ti, blk in enumerate(tb):
        rows = slice(ti * tile, (ti + 1) * tile)
        real_blocks = sb[rows][qi[rows] >= 0]
        if blk < nb:
            assert (real_blocks == blk).all()
        else:
            assert real_blocks.size == 0


@given(seed=st.integers(0, 30), bs=st.sampled_from([16, 32]),
       k=st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_moba_output_is_convex_combination(seed, bs, k):
    """Each output row lies in the convex hull of V rows (softmax
    property) — catches normalization/merge bugs for any (B, k)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    n, d = 64, 8
    q = jax.random.normal(keys[0], (1, 1, n, d))
    kk = jax.random.normal(keys[1], (1, 1, n, d))
    v = jax.random.uniform(keys[2], (1, 1, n, d))  # positive
    cfg = MoBAConfig(block_size=bs, top_k=k)
    out = np.asarray(moba.moba_attention_reference(q, kk, v, cfg))[0, 0]
    vmin, vmax = float(v.min()), float(v.max())
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()


@given(seed=st.integers(0, 30))
@settings(max_examples=6, deadline=None)
def test_sparse_xla_equals_reference(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (1, 2, 64, 16))
    kk = jax.random.normal(keys[1], (1, 1, 64, 16))
    v = jax.random.normal(keys[2], (1, 1, 64, 16))
    cfg = MoBAConfig(block_size=16, top_k=2)
    from repro.kernels import ref
    a = ref.moba_sparse_xla(q, kk, v, cfg, tile=16)
    b = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-4, atol=3e-4)


@given(width=st.sampled_from([2, 3, 5]), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_key_conv_shift_equivariance(width, seed):
    """Causal depthwise conv commutes with temporal shift (in the valid
    interior) — the structural property the router exploits."""
    from repro.core.key_conv import apply_key_conv, init_key_conv
    w = init_key_conv(jax.random.PRNGKey(0), width, 1, 8)
    k = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 32, 8))
    out = apply_key_conv(w, k)
    k_shift = jnp.roll(k, 4, axis=2)
    out_shift = apply_key_conv(w, k_shift)
    np.testing.assert_allclose(np.asarray(out_shift[:, :, 4 + width:]),
                               np.asarray(out[:, :, width:-4]),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------ quantized page pools
@given(kv_dtype=st.sampled_from(["int8", "fp8"]),
       scale_exp=st.integers(-30, 20), ps=st.sampled_from([1, 4, 12, 16]),
       zero=st.booleans(), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_quantize_roundtrip_error_bound(kv_dtype, scale_exp, ps, zero,
                                        seed):
    """dequant(quant(x)) stays within the dtype's rounding bound for
    magnitudes from subnormal-scale to 2^20, single-token pages, and
    the all-zero page (which must round-trip exactly via scale 1.0)."""
    from repro.core import quantization as Q
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(3, ps, 2, 4)) * 2.0 ** scale_exp
    if zero:
        x[:] = 0.0
    x = jnp.asarray(x, jnp.float32)
    scale = Q.compute_scale(x, (1, 3), kv_dtype)
    s4 = scale[:, None, :, None]
    back = np.asarray(Q.dequantize(Q.quantize(x, s4, kv_dtype), s4))
    if zero:
        assert (np.asarray(scale) == 1.0).all()
        assert (back == 0.0).all()
        return
    err = np.abs(back - np.asarray(x))
    s = np.asarray(s4)
    if kv_dtype == "int8":
        bound = s * (0.5 + 1e-6)
    else:  # e4m3: half-ulp relative + subnormal absolute floor
        bound = np.abs(np.asarray(x)) * 2.0 ** -4 + s * 2.0 ** -10
    assert (err <= bound + np.abs(np.asarray(x)) * 1e-6).all()


@given(kv_dtype=st.sampled_from(["int8", "fp8"]),
       ps=st.sampled_from([3, 7, 12, 16]), n_tok=st.integers(1, 24),
       seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_quantized_prefill_roundtrip_any_geometry(kv_dtype, ps, n_tok,
                                                  seed):
    """One-shot prefill into a quantized pool, then densify: within the
    dtype's per-page bound of the fp32 pool for any page_size (incl.
    ps % sublane != 0) and any ragged length (incl. single tokens)."""
    from repro.serving import paged_cache as PC
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("moba-340m")
    hkv, d = cfg.num_kv_heads, cfg.resolved_head_dim
    npg = -(-n_tok // ps)
    rng = np.random.default_rng(seed)
    kc = jnp.asarray(rng.normal(size=(1, hkv, npg * ps, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, hkv, npg * ps, d)), jnp.float32)
    table = jnp.asarray(np.arange(npg, dtype=np.int32)[None])
    kv_lens = jnp.asarray([n_tok], jnp.int32)

    def densified(kv_dt):
        pool = PC.init_page_pool(cfg, npg, ps, with_centroids=True,
                                 dtype=jnp.float32, kv_dtype=kv_dt)
        pool = PC.paged_append_prefill(pool, table, kv_lens, kc, vc)
        kf, vf = PC.paged_gather_kv(pool, table)
        return np.asarray(kf)[:, :, :n_tok], np.asarray(vf)[:, :, :n_tok]

    k0, v0 = densified("fp32")
    k1, v1 = densified(kv_dtype)
    tol = {"int8": 5e-2, "fp8": 2e-1}[kv_dtype]
    rel = max(np.abs(k0).max(), np.abs(v0).max())
    assert np.abs(k1 - k0).max() <= tol * rel
    assert np.abs(v1 - v0).max() <= tol * rel
