"""Data pipeline, optimizer, checkpoint manager, SNR model tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core import snr
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.data.niah import make_niah_batch, router_retrieval_accuracy
from repro.optim import adamw, compression


# ------------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=3)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts draw half batches each, different content
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).batch_at(7)
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).batch_at(7)
    assert h0["tokens"].shape == (4, 33)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_learnable_structure():
    """Markov corpus must have far-below-uniform conditional entropy."""
    cfg = DataConfig(vocab_size=512, seq_len=256, global_batch=16)
    toks = SyntheticLM(cfg).batch_at(0)["tokens"]
    # bigram predictability: count repeated (prev, next) pairs
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    top1 = [max(np.bincount(v)) / len(v) for v in pairs.values()
            if len(v) >= 5]
    assert np.mean(top1) > 3.0 / 512  # ≫ uniform


def test_niah_batch():
    rng = np.random.default_rng(0)
    b = make_niah_batch(rng, 8, 128, 64)
    assert b["tokens"].shape == (8, 128)
    for i in range(8):
        p = b["needle_pos"][i]
        assert b["tokens"][i, p] == 63
        np.testing.assert_array_equal(b["tokens"][i, p + 1:p + 5],
                                      b["value"][i])
    sel = np.stack([b["needle_pos"] // 16, np.zeros(8, np.int32)], 1)
    assert router_retrieval_accuracy(sel, b["needle_pos"], 16) == 1.0


# ------------------------------------------------------------------ optim
def test_adamw_converges_quadratic():
    cfg = TrainConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.adamw_init(params)
    lr_fn = adamw.cosine_schedule(cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.adamw_update(params, grads, state, cfg,
                                              lr_fn)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_weight_decay_skips_norms():
    cfg = TrainConfig(learning_rate=0.0, weight_decay=1.0)
    # lr=0 → no update at all regardless of decay; use lr>0 and zero grads
    cfg = TrainConfig(learning_rate=0.1, weight_decay=1.0, warmup_steps=0,
                      total_steps=10)
    params = {"w_gate": jnp.ones((2,)), "norm1": jnp.ones((2,))}
    state = adamw.adamw_init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    newp, _, _ = adamw.adamw_update(params, grads, state, cfg)
    assert float(newp["norm1"][0]) == 1.0          # no decay on norms
    assert float(newp["w_gate"][0]) < 1.0          # decayed


def test_compression_error_feedback_unbiased():
    """Over many steps, quantization error must not accumulate."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros((256,))
    total_true = np.zeros(256)
    total_sent = np.zeros(256)
    for _ in range(50):
        g = jnp.asarray(rng.normal(0, 1, 256), jnp.float32)
        q, scale, residual = compression.compress(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(compression.decompress(q, scale))
    # residual bounded by one quantization step
    assert float(jnp.abs(residual).max()) < 0.1
    np.testing.assert_allclose(total_sent + np.asarray(residual),
                               total_true, atol=1e-3)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data_step": step})
    assert mgr.all_steps() == [2, 3]  # retention keeps last 2
    restored, extra, step = mgr.restore(jax.eval_shape(lambda: tree))
    assert step == 3 and extra["data_step"] == 3
    np.testing.assert_allclose(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["nested"]["b"],
                                  tree["nested"]["b"])


def test_checkpoint_atomicity(tmp_path):
    """A tmp dir left by a crashed save must not be listed as a step."""
    mgr = CheckpointManager(str(tmp_path), async_writes=False)
    os.makedirs(tmp_path / "tmp.step_00000009")
    mgr.save(1, {"x": jnp.zeros(2)})
    assert mgr.all_steps() == [1]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_writes=True)
    mgr.save(5, {"x": jnp.full((8,), 2.0)})
    mgr.wait()
    restored, _, step = mgr.restore({"x": jnp.zeros(8)})
    assert step == 5
    np.testing.assert_allclose(restored["x"], 2.0)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_writes=False)
    mgr.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros((5,))})


# -------------------------------------------------------------------- snr
def test_snr_formula():
    assert snr.snr(64, 128, 1.0) == pytest.approx((64 / 256) ** 0.5)
    # halving B buys sqrt(2) SNR (paper's principle 1)
    assert snr.snr(64, 64, 1.0) / snr.snr(64, 128, 1.0) == \
        pytest.approx(2 ** 0.5)


def test_p_fail_monotone_in_block_size():
    ps = [snr.p_fail(64, b, 0.5) for b in (64, 128, 256, 512)]
    assert all(a < b for a, b in zip(ps, ps[1:]))


def test_clustering_raises_snr():
    base = snr.effective_gap(0.5)
    clustered = snr.effective_gap(0.5, m=4, mu_cluster=0.3)
    assert clustered > base


def test_empirical_pfail_matches_theory():
    """Monte-carlo check of Φ(−SNR) (coarse: 300 trials)."""
    import jax
    d, bs, delta = 64, 64, 0.8
    fails, pairs = 0, 0
    key = jax.random.PRNGKey(0)
    for t in range(60):
        key, k2 = jax.random.split(key)
        prob = snr.make_planted_problem(k2, 1024, d, bs, delta)
        nb = 1024 // bs
        cents = prob.keys.reshape(nb, bs, d).mean(1)
        scores = np.asarray(cents @ prob.q)
        sig = scores[prob.signal_block]
        fails += int((np.delete(scores, prob.signal_block) > sig).sum())
        pairs += nb - 1
    emp = fails / pairs
    theory = snr.p_fail(d, bs, delta)
    assert abs(emp - theory) < 0.1


def test_required_snr():
    # need higher SNR for more blocks at fixed k
    assert snr.required_snr(4096, 8) > snr.required_snr(64, 8)
