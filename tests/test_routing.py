"""Routing + varlen layout unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoBAConfig
from repro.core import moba, routing


def make_qkv(seed=0, b=2, h=4, hkv=2, n=256, d=32, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, n, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, n, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, n, d), dtype)
    return q, k, v


def test_centroids_mean():
    k = jnp.arange(2 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 4)
    c = routing.block_centroids(k, 4)
    expected = k.reshape(2, 2, 4, 4).mean(2)
    np.testing.assert_allclose(c, expected, rtol=1e-6)


def test_centroids_ragged_tail():
    k = jnp.ones((1, 10, 4))
    c = routing.block_centroids(k, 4)
    assert c.shape == (1, 3, 4)
    np.testing.assert_allclose(c, 1.0, rtol=1e-6)


def test_selection_own_block_always_selected():
    q, k, _ = make_qkv()
    cfg = MoBAConfig(block_size=32, top_k=3)
    sel = moba.moba_selection(q, k, cfg)
    own = jnp.arange(256) // 32
    assert bool((sel == own[None, None, :, None]).any(-1).all())


def test_selection_causal():
    q, k, _ = make_qkv()
    cfg = MoBAConfig(block_size=32, top_k=3)
    sel = moba.moba_selection(q, k, cfg)
    own = jnp.arange(256) // 32
    nb = 256 // 32
    valid = sel < nb
    assert bool(jnp.where(valid, sel <= own[None, None, :, None], True).all())


def test_selection_early_queries_sentinel():
    q, k, _ = make_qkv()
    cfg = MoBAConfig(block_size=32, top_k=4)
    sel = moba.moba_selection(q, k, cfg)
    nb = 256 // 32
    # query 0 has exactly 1 valid block; 3 sentinels
    assert int((sel[:, :, 0] == nb).sum(-1).min()) == 3


def test_sparsity_accounting():
    """(B,k) pairs keep k/n attended fraction — the paper's 7/8 sparsity."""
    n = 8192
    for bs, k in [(512, 2), (256, 4), (128, 8)]:
        nb = n // bs
        assert k / nb == pytest.approx(1 / 8)


def test_varlen_layout_roundtrip():
    q, k, _ = make_qkv()
    cfg = MoBAConfig(block_size=32, top_k=3)
    sel = moba.moba_selection(q, k, cfg)[0, 0]
    n, nb, tile = 256, 8, 16
    lay = routing.build_varlen_layout(sel, n, nb, tile)
    qi, sb = np.asarray(lay.q_index), np.asarray(lay.slot_block)
    tb = np.asarray(lay.tile_block)
    pairs = {(int(qi[s]), int(sb[s])) for s in range(len(qi)) if qi[s] >= 0}
    expected = {(t, int(j)) for t in range(n) for j in np.asarray(sel)[t]
                if j < nb}
    assert pairs == expected
    # tile homogeneity: every real slot in tile ti has block tb[ti]
    for ti in range(len(tb)):
        rows = slice(ti * tile, (ti + 1) * tile)
        real = sb[rows][qi[rows] >= 0]
        if tb[ti] < nb:
            assert (real == tb[ti]).all()
        else:
            assert real.size == 0
    # pair_slot inverse mapping
    ps = np.asarray(lay.pair_slot)
    for t in range(n):
        for kk in range(3):
            s = ps[t, kk]
            if np.asarray(sel)[t, kk] < nb:
                assert qi[s] == t and sb[s] == np.asarray(sel)[t, kk]


def test_layout_capacity_static():
    assert routing.layout_capacity(256, 3, 8, 16) == 256 * 3 + 8 * 16
