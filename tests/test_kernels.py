"""Per-kernel allclose vs the pure-jnp oracles, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoBAConfig
from repro.core import moba, routing
from repro.kernels import ops, ref
from repro.kernels.centroids import block_centroids_kernel
from repro.kernels.flash_topk import flash_topk
from repro.kernels.moba_fwd import moba_fwd


def make_qkv(seed=0, b=1, h=4, hkv=2, n=256, d=32, dtype=jnp.float32,
             scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, n, d), dtype) * scale
    k = jax.random.normal(ks[1], (b, hkv, n, d), dtype) * scale
    v = jax.random.normal(ks[2], (b, hkv, n, d), dtype)
    return q, k, v


TOLS = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,bs", [(256, 32), (128, 16), (512, 64), (192, 32)])
def test_centroid_kernel_sweep(n, bs, dtype):
    k = jax.random.normal(jax.random.PRNGKey(n), (4, n, 32), dtype)
    out = block_centroids_kernel(k, bs)
    expected = ref.centroids_ref(k, bs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("grid", ["grouped", "flat"])
@pytest.mark.parametrize("n,bs,k,qt", [(256, 32, 3, 64), (256, 32, 8, 128),
                                       (512, 64, 2, 128), (128, 16, 4, 32)])
def test_flash_topk_sweep(n, bs, k, qt, grid):
    q, kk, _ = make_qkv(n + k, n=n)
    cfg = MoBAConfig(block_size=bs, top_k=k)
    cents = routing.block_centroids(kk, bs).reshape(-1, n // bs, 32)
    sel_k = flash_topk(q.reshape(-1, n, 32), cents, k, bs,
                       group=2, num_q_heads=4, q_tile=qt, grid=grid)
    sel_r = moba.moba_selection(q, kk, cfg).reshape(-1, n, k)
    assert int((sel_k != sel_r).sum()) == 0


@pytest.mark.parametrize("grid", ["grouped", "flat"])
def test_flash_topk_padded_centroid_edge(grid):
    """nb % cent_tile != 0: the wrapper pads the centroid array and the
    kernels must never select a pad block (9 blocks, cent_tile 8)."""
    n, bs, k = 288, 32, 4
    q, kk, _ = make_qkv(7, n=n)
    cfg = MoBAConfig(block_size=bs, top_k=k)
    cents = routing.block_centroids(kk, bs).reshape(-1, n // bs, 32)
    sel_k = flash_topk(q.reshape(-1, n, 32), cents, k, bs,
                       group=2, num_q_heads=4, q_tile=96, cent_tile=8,
                       grid=grid)
    sel_r = moba.moba_selection(q, kk, cfg).reshape(-1, n, k)
    assert int((sel_k != sel_r).sum()) == 0


def test_flash_topk_unknown_grid_rejected():
    q, kk, _ = make_qkv(1, n=128)
    cents = routing.block_centroids(kk, 32).reshape(-1, 4, 32)
    with pytest.raises(ValueError, match="grouped"):
        flash_topk(q.reshape(-1, 128, 32), cents, 2, 32,
                   group=2, num_q_heads=4, grid="typo")


def test_flash_topk_bidirectional():
    q, kk, _ = make_qkv(3, n=128)
    cfg = MoBAConfig(block_size=16, top_k=3, causal=False)
    cents = routing.block_centroids(kk, 16).reshape(-1, 8, 32)
    sel_k = flash_topk(q.reshape(-1, 128, 32), cents, 3, 16,
                       group=2, num_q_heads=4, q_tile=64, causal=False)
    sel_r = moba.moba_selection(q, kk, cfg).reshape(-1, 128, 3)
    assert int((sel_k != sel_r).sum()) == 0


def test_moba_fwd_partials_vs_oracle():
    """Direct check of the forward kernel's (o, m, l) partials."""
    q, k, v = make_qkv(5, b=1, h=2, hkv=1, n=128, d=16)
    cfg = MoBAConfig(block_size=16, top_k=3)
    tile = 32
    nb = 8
    sel = moba.moba_selection(q, k, cfg).reshape(2, 128, 3)
    lay = jax.vmap(
        lambda s: routing.build_varlen_layout(s, 128, nb, tile))(sel)
    qf = q.reshape(2, 128, 16)
    qi = jnp.maximum(lay.q_index, 0)
    q_sorted = jnp.take_along_axis(qf, qi[..., None], axis=1)
    q_pos = jnp.where(lay.q_index >= 0, qi, -1).astype(jnp.int32)
    k_blocks = k.reshape(1, nb, 16, 16)
    v_blocks = v.reshape(1, nb, 16, 16)
    o, m, l = moba_fwd(lay.tile_block, q_sorted, q_pos, k_blocks, v_blocks,
                       scale=0.25, block_size=16, n_tokens=128,
                       num_q_heads=2, group=2, q_tile=tile)
    for hh in range(2):
        oracle = ref.moba_partials_ref(
            q_sorted[hh], q_pos[hh], lay.slot_block[hh],
            k_blocks[0], v_blocks[0], 0.25, 16)
        np.testing.assert_allclose(np.asarray(o[hh]), np.asarray(oracle.o),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(m[hh]), np.asarray(oracle.m),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(l[hh]), np.asarray(oracle.l),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,bs,k,h,hkv,d",
                         [(256, 32, 3, 4, 2, 32),
                          (128, 16, 8, 2, 1, 16),
                          (512, 128, 2, 2, 2, 64),
                          (256, 64, 4, 8, 2, 32)])
def test_flash_moba_end_to_end_sweep(n, bs, k, h, hkv, d, dtype):
    q, kk, v = make_qkv(n * k + h, h=h, hkv=hkv, n=n, d=d, dtype=dtype)
    cfg = MoBAConfig(block_size=bs, top_k=k)
    o_k = ops.flash_moba(q, kk, v, cfg, q_tile=min(128, n))
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               **TOLS[dtype])


def test_flash_moba_ragged_kv():
    """N not a multiple of block size exercises the tail mask."""
    q, kk, v = make_qkv(17, n=192, d=32)
    cfg = MoBAConfig(block_size=128, top_k=2)
    o_k = ops.flash_moba(q, kk, v, cfg, q_tile=64)
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------- GQA grid/dtype matrix
@pytest.mark.parametrize("grid", ["grouped", "flat"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("group,bs", [(1, 32), (2, 32), (4, 32),
                                      (1, 64), (2, 64), (4, 64),
                                      (2, 128)])
def test_flash_moba_gqa_grid_matrix(group, bs, dtype, grid):
    """End-to-end equivalence across GQA group sizes × block sizes ×
    dtypes, through both the MXU grouped/tiled and legacy flat grids."""
    h, n, d = 4, 256, 32
    hkv = h // group
    k = 2 if bs >= 128 else 3
    q, kk, v = make_qkv(group * 31 + bs, h=h, hkv=hkv, n=n, d=d,
                        dtype=dtype)
    cfg = MoBAConfig(block_size=bs, top_k=k)
    o_k = ops.flash_moba(q, kk, v, cfg, q_tile=128, grid=grid)
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               **TOLS[dtype])


@pytest.mark.parametrize("grid", ["grouped", "flat"])
def test_flash_moba_odd_length(grid):
    """Nq not a multiple of q_tile: the wrapper pads to the tile with
    sentinel-routed rows (q_pos = -1) and slices the pad back off —
    forward and gradients must match the oracle exactly as in the
    aligned case (the ragged-length satellite)."""
    q, kk, v = make_qkv(43, n=200, d=32)
    cfg = MoBAConfig(block_size=32, top_k=3)
    o_k = ops.flash_moba(q, kk, v, cfg, q_tile=128, grid=grid)
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)

    def loss_k(q, k, v):
        return jnp.sum(ops.flash_moba(q, k, v, cfg, q_tile=128,
                                      grid=grid) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(moba.moba_attention_reference(q, k, v, cfg) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, kk, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, kk, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("kb_tile", [8, 16, 64])
def test_flash_moba_kb_tile_sweep(kb_tile):
    """Explicit kb_tile settings (sub-block K/V streaming) are
    numerically identical to whole-block processing."""
    q, kk, v = make_qkv(53, n=256, d=32)
    cfg = MoBAConfig(block_size=64, top_k=3)
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    o_k = ops.flash_moba(q, kk, v, cfg, q_tile=64, kb_tile=kb_tile,
                         grid="grouped")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)


def test_flash_moba_grads_match_reference():
    q, kk, v = make_qkv(23, n=256, d=32)
    cfg = MoBAConfig(block_size=32, top_k=3)

    def loss_k(q, k, v):
        return jnp.sum(ops.flash_moba(q, k, v, cfg, q_tile=64) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(moba.moba_attention_reference(q, k, v, cfg) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, kk, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, kk, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_moba_sparse_xla_matches_reference():
    q, kk, v = make_qkv(29, n=256, d=32)
    cfg = MoBAConfig(block_size=32, top_k=3)
    o_s = ref.moba_sparse_xla(q, kk, v, cfg, tile=64)
    o_r = moba.moba_attention_reference(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)


def test_moba_sparse_xla_grads():
    q, kk, v = make_qkv(31, n=128, d=16)
    cfg = MoBAConfig(block_size=16, top_k=4)

    def loss_s(q, k, v):
        return jnp.sum(ref.moba_sparse_xla(q, k, v, cfg, tile=32) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(moba.moba_attention_reference(q, k, v, cfg) ** 2)

    gs = jax.grad(loss_s, argnums=(0, 1, 2))(q, kk, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, kk, v)
    for a, b in zip(gs, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_flash_moba_with_key_conv_grads():
    """Gradient flows to key-conv weights through the kernel path."""
    from repro.core.key_conv import apply_key_conv, init_key_conv
    q, kk, v = make_qkv(37, n=128, d=16)
    cfg = MoBAConfig(block_size=16, top_k=3, key_conv_width=3)
    w = init_key_conv(jax.random.PRNGKey(0), 3, 2, 16)

    def loss(w):
        kc = apply_key_conv(w, kk)
        return jnp.sum(ops.flash_moba(q, kc, v, cfg, q_tile=32) ** 2)

    g = jax.grad(loss)(w)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0
