"""Per-page K/V quantization for the paged serving cache.

Decode is memory-bound (PAPERS.md: "Rethinking LLM Inference
Bottlenecks"), and the paper's SNR analysis shows retrieval accuracy is
governed by the *routing* signal — centroid scores — not by the page
payload precision.  So the pool stores K/V pages in int8 or fp8
(e4m3) with one fp32 scale per (page, kv head), while centroids,
key-conv ring buffers, and every routing input stay fp32: the router
is bitwise identical across ``kv_dtype`` modes (pinned by
tests/test_quantized_pages.py) and only the attended values carry
quantization error.

Scale layout (DESIGN.md §2): ``scales_k`` / ``scales_v`` are
``(num_pages, hkv)`` fp32 pool leaves living beside ``pages_k`` /
``pages_v`` in :data:`repro.serving.paged_cache.PAGE_LEAVES` — so COW
page copies and host swap move payload + scales atomically with no
extra plumbing.  A page's scale is ``amax / qmax`` over its *valid*
tokens (1.0 for an all-zero or empty page, keeping dequant a no-op),
symmetric, zero-point-free:

    payload = clip(round(x / scale))     (int8; fp8 rounds in the cast)
    x̂       = payload · scale

Quantization happens on append (``paged_append_prefill`` /
``paged_append_decode`` requantize each touched page from an fp32
staging view); dequantization happens at the last possible moment — in
VMEM inside the Pallas decode kernels, or at the densify/gather step of
the XLA paths — so HBM only ever holds the low-precision payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ``fp32`` = unquantized: pages stored at the engine compute dtype with
# no scales leaves, byte-for-byte the pre-quantization pool layout.
KV_DTYPES = ("fp32", "int8", "fp8")

PAYLOAD_DTYPES = {
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
}

# symmetric clip points: int8 keeps ±127 (no -128 asymmetry); e4m3's
# largest finite is 448 (the fn variant has no inf to overflow into)
QMAX = {
    "int8": 127.0,
    "fp8": 448.0,
}


def kv_dtype_of(dtype) -> str:
    """Pool payload dtype → ``kv_dtype`` name (``"fp32"`` for any
    unquantized storage dtype, bf16 included)."""
    d = jnp.dtype(dtype)
    for name, pd in PAYLOAD_DTYPES.items():
        if d == jnp.dtype(pd):
            return name
    return "fp32"


def payload_dtype(kv_dtype: str):
    if kv_dtype not in PAYLOAD_DTYPES:
        raise ValueError(
            f"kv_dtype {kv_dtype!r} has no quantized payload; "
            f"quantized modes: {sorted(PAYLOAD_DTYPES)}")
    return PAYLOAD_DTYPES[kv_dtype]


def compute_scale(x: jax.Array, reduce_axes, kv_dtype: str,
                  where=None) -> jax.Array:
    """Per-group fp32 scale ``amax / qmax`` with amax taken over
    ``reduce_axes`` (optionally masked by ``where``); all-zero groups
    get scale 1.0 so dequantization stays a no-op."""
    mag = jnp.abs(x.astype(jnp.float32))
    if where is not None:
        mag = mag * where.astype(jnp.float32)
    amax = jnp.max(mag, axis=reduce_axes)
    return jnp.where(amax > 0.0, amax / QMAX[kv_dtype], 1.0)


def quantize(x: jax.Array, scale: jax.Array, kv_dtype: str) -> jax.Array:
    """fp32 values → payload dtype.  ``scale`` must broadcast against
    ``x`` (callers expand the per-(page, head) scale themselves)."""
    qmax = QMAX[kv_dtype]
    y = x.astype(jnp.float32) / scale
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    return jnp.clip(y, -qmax, qmax).astype(PAYLOAD_DTYPES[kv_dtype])


def dequantize(payload: jax.Array, scale: jax.Array) -> jax.Array:
    """Payload → fp32.  Exact inverse of the storage transform up to the
    rounding the quantizer already paid."""
    return payload.astype(jnp.float32) * scale
