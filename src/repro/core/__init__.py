from repro.core import attention, key_conv, moba, routing, snr  # noqa: F401
