"""Dense and sliding-window attention + the per-layer dispatcher.

These are the baselines the paper compares against (dense) and interleaves
with (SWA, window 256, odd layers).  All math in fp32, inputs bf16.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.core.moba import moba_attention, moba_decode_attention

NEG_INF = -1e30


def _grouped_scores(q, k, scale):
    b, h, nq, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(b, hkv, h // hkv, nq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k.astype(jnp.float32)) * scale
    return s.reshape(b, h, nq, k.shape[2])


def _apply_and_project(p, v, out_dtype):
    b, h, nq, n = p.shape
    hkv = v.shape[1]
    pg = p.reshape(b, hkv, h // hkv, nq, n)
    o = jnp.einsum("bhgqs,bhsd->bhgqd", pg, v.astype(jnp.float32))
    return o.reshape(b, h, nq, v.shape[-1]).astype(out_dtype)


def dense_attention(q, k, v, causal: bool = True,
                    q_positions: Optional[jax.Array] = None,
                    kv_len: Optional[jax.Array] = None,
                    window: int = 0,
                    scale: Optional[float] = None) -> jax.Array:
    """Dense (optionally sliding-window) attention with GQA grouping.

    window > 0 keeps keys with q_pos - window < s <= q_pos.
    ``q_positions`` may be (Nq,) shared or (B, Nq) per-sequence (ragged
    serving batches); ``kv_len`` a scalar or (B,) per-sequence lengths.
    """
    b, h, nq, d = q.shape
    n = k.shape[2]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_positions is None:
        q_positions = jnp.arange(nq) + (n - nq)
    s = _grouped_scores(q, k, scale)
    spos = jnp.arange(n)
    qp = jnp.asarray(q_positions)
    qp = qp[None] if qp.ndim == 1 else qp                    # (1|B, Nq)
    mask = jnp.ones((qp.shape[0], nq, n), bool)
    if causal:
        mask &= qp[:, :, None] >= spos[None, None, :]
    if window:
        mask &= qp[:, :, None] - spos[None, None, :] < window
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        kvl = kvl.reshape((-1, 1, 1)) if kvl.ndim else kvl
        mask &= spos[None, None, :] < kvl
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, :, None], p, 0.0)
    return _apply_and_project(p, v, q.dtype)


def attention_dispatch(cfg: AttentionConfig, kind: str, q, k, v,
                       key_conv_weights=None,
                       q_positions=None, kv_len=None,
                       moba_impl: str = "reference",
                       causal: bool = True,
                       centroids=None) -> jax.Array:
    """Route to dense / swa / moba according to the layer kind."""
    if kind == "dense":
        return dense_attention(q, k, v, causal=causal,
                               q_positions=q_positions, kv_len=kv_len,
                               scale=cfg.scale)
    if kind == "swa":
        return dense_attention(q, k, v, causal=causal,
                               q_positions=q_positions, kv_len=kv_len,
                               window=cfg.window, scale=cfg.scale)
    if kind == "moba":
        assert cfg.moba is not None
        if q.shape[2] == 1 and kv_len is not None:
            if moba_impl.startswith("sp"):
                from repro.distributed.moba_sp import moba_decode_cp
                return moba_decode_cp(q, k, v, kv_len, cfg.moba,
                                      scale=cfg.scale, centroids=centroids)
            return moba_decode_attention(q, k, v, kv_len, cfg.moba,
                                         scale=cfg.scale,
                                         centroids=centroids)
        return moba_attention(q, k, v, cfg.moba,
                              key_conv_weights=key_conv_weights,
                              impl=moba_impl, q_positions=q_positions,
                              scale=cfg.scale)
    raise ValueError(f"unknown attention kind {kind!r}")
