"""Dense and sliding-window attention + the per-layer dispatcher.

These are the baselines the paper compares against (dense) and interleaves
with (SWA, window 256, odd layers).  All math in fp32, inputs bf16.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig

NEG_INF = -1e30


def _grouped_scores(q, k, scale):
    b, h, nq, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(b, hkv, h // hkv, nq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k.astype(jnp.float32)) * scale
    return s.reshape(b, h, nq, k.shape[2])


def _apply_and_project(p, v, out_dtype):
    b, h, nq, n = p.shape
    hkv = v.shape[1]
    pg = p.reshape(b, hkv, h // hkv, nq, n)
    o = jnp.einsum("bhgqs,bhsd->bhgqd", pg, v.astype(jnp.float32))
    return o.reshape(b, h, nq, v.shape[-1]).astype(out_dtype)


def dense_attention(q, k, v, causal: bool = True,
                    q_positions: Optional[jax.Array] = None,
                    kv_len: Optional[jax.Array] = None,
                    window: int = 0,
                    scale: Optional[float] = None) -> jax.Array:
    """Dense (optionally sliding-window) attention with GQA grouping.

    window > 0 keeps keys with q_pos - window < s <= q_pos.
    ``q_positions`` may be (Nq,) shared or (B, Nq) per-sequence (ragged
    serving batches); ``kv_len`` a scalar or (B,) per-sequence lengths.
    """
    b, h, nq, d = q.shape
    n = k.shape[2]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_positions is None:
        q_positions = jnp.arange(nq) + (n - nq)
    s = _grouped_scores(q, k, scale)
    spos = jnp.arange(n)
    qp = jnp.asarray(q_positions)
    qp = qp[None] if qp.ndim == 1 else qp                    # (1|B, Nq)
    mask = jnp.ones((qp.shape[0], nq, n), bool)
    if causal:
        mask &= qp[:, :, None] >= spos[None, None, :]
    if window:
        mask &= qp[:, :, None] - spos[None, None, :] < window
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        kvl = kvl.reshape((-1, 1, 1)) if kvl.ndim else kvl
        mask &= spos[None, None, :] < kvl
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, :, None], p, 0.0)
    return _apply_and_project(p, v, q.dtype)


def attention_dispatch(cfg: AttentionConfig, kind: str, q, k, v,
                       key_conv_weights=None,
                       q_positions=None, kv_len=None,
                       backend: str = "reference",
                       causal: bool = True,
                       centroids=None) -> jax.Array:
    """Route to a registered attention backend (``core.backends``) by
    name + capability query — no per-implementation branches here.

    ``kind`` ∈ {dense, swa, moba} selects the layer behaviour; ``backend``
    selects the implementation.  Single-token calls against a cache
    (``q`` length 1 with ``kv_len``) resolve the decode phase, everything
    else the prefill phase.
    """
    from repro.core import backends as B

    needs_kconv = kind == "moba" and key_conv_weights is not None
    if kind == "moba":
        assert cfg.moba is not None
        if needs_kconv:
            from repro.core.key_conv import apply_key_conv
            k = apply_key_conv(key_conv_weights, k)
    if q.shape[2] == 1 and kv_len is not None:
        be = B.resolve(backend, kind=kind, phase="decode", cache="dense",
                       key_conv=needs_kconv)
        return be.decode(cfg, kind, q, k, v, kv_len, centroids=centroids,
                         q_positions=q_positions)
    be = B.resolve(backend, kind=kind, phase="prefill", cache="dense",
                   key_conv=needs_kconv)
    return be.prefill(cfg, kind, q, k, v, q_positions=q_positions,
                      kv_len=kv_len, causal=causal)
