"""The paper's statistical model of MoBA block selection (Section 3 + App A).

SNR = Δμ_eff · sqrt(d / 2B),   p_fail = Φ(−SNR)
Δμ_eff = Δμ + (m−1)(μ_cluster − μ_noise)

plus a synthetic planted-signal generator used by benchmarks/fig2_snr.py to
validate the formula empirically (retrieval failure rate vs theory).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


def effective_gap(delta_mu: float, m: int = 1, mu_cluster: float = 0.0,
                  mu_noise: float = 0.0) -> float:
    """Δμ_eff with m clustered signal tokens (paper Eq. after (2))."""
    return delta_mu + (m - 1) * (mu_cluster - mu_noise)


def snr(d: int, block_size: int, delta_mu_eff: float) -> float:
    """Central formula, paper Eq. (3)."""
    return delta_mu_eff * math.sqrt(d / (2.0 * block_size))


def p_fail(d: int, block_size: int, delta_mu_eff: float) -> float:
    """Probability a single noise block outranks the signal block:
    Φ(−SNR)."""
    return 0.5 * math.erfc(snr(d, block_size, delta_mu_eff) / math.sqrt(2.0))


def required_snr(num_blocks: int, top_k: int) -> float:
    """SNR needed for reliable top-k retrieval among n blocks:
    SNR > Φ⁻¹(1 − k/n)  (paper App. A.4)."""
    q = 1.0 - top_k / num_blocks
    # inverse normal CDF via Acklam-style rational approx (scipy-free)
    return _norm_ppf(q)


def _norm_ppf(p: float) -> float:
    # Peter Acklam's rational approximation, |eps| < 4.5e-4 relative.
    if not 0.0 < p < 1.0:
        raise ValueError("p in (0,1)")
    a = [-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00]
    dd = [7.784695709041462e-03, 3.224671290700398e-01,
          2.445134137142996e+00, 3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        ql = math.sqrt(-2 * math.log(p))
        num = ((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4])
        num = num * ql + c[5]
        den = (((dd[0] * ql + dd[1]) * ql + dd[2]) * ql + dd[3]) * ql + 1
        return num / den
    if p > phigh:
        ql = math.sqrt(-2 * math.log(1 - p))
        num = ((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4])
        num = num * ql + c[5]
        den = (((dd[0] * ql + dd[1]) * ql + dd[2]) * ql + dd[3]) * ql + 1
        return -num / den
    ql = p - 0.5
    r = ql * ql
    num = ((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
    num = (num * r + a[5]) * ql
    den = ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
    return num / den


class PlantedProblem(NamedTuple):
    """Synthetic retrieval instance matching App. A's generative model."""
    q: jax.Array          # (d,)
    keys: jax.Array       # (N, d)
    signal_block: int


def make_planted_problem(key: jax.Array, n_tokens: int, d: int,
                         block_size: int, delta_mu: float,
                         m: int = 1, mu_cluster: float = 0.0,
                         signal_block: int = 0) -> PlantedProblem:
    """Noise keys uniform on the sphere (q·k ~ mean 0, var 1/d after
    normalization); signal key with E[q·k*] = delta_mu; m−1 clustered keys
    at affinity mu_cluster, all placed in ``signal_block``."""
    kq, kn, ks = jax.random.split(key, 3)
    q = jax.random.normal(kq, (d,))
    q = q / jnp.linalg.norm(q)
    keys = jax.random.normal(kn, (n_tokens, d))
    keys = keys / jnp.linalg.norm(keys, axis=-1, keepdims=True)

    def plant(vec, mu, seed):
        # component along q has mean mu; orthogonal part rescaled to keep
        # the vector unit-norm (mu<1 assumed).
        orth = vec - (vec @ q) * q
        orth = orth / jnp.linalg.norm(orth)
        return mu * q + math.sqrt(max(1.0 - mu * mu, 1e-9)) * orth

    base = signal_block * block_size
    keys = keys.at[base].set(plant(keys[base], delta_mu, 0))
    for i in range(1, m):
        keys = keys.at[base + i].set(plant(keys[base + i], mu_cluster, i))
    return PlantedProblem(q, keys, signal_block)


def empirical_retrieval(problem: PlantedProblem, block_size: int,
                        top_k: int) -> jax.Array:
    """Return True iff the signal block is ranked in the top-k by centroid
    scores (the event whose failure probability the theory predicts)."""
    n = problem.keys.shape[0]
    nb = n // block_size
    cents = problem.keys.reshape(nb, block_size, -1).mean(axis=1)
    scores = cents @ problem.q
    top = jax.lax.top_k(scores, top_k)[1]
    return jnp.any(top == problem.signal_block)
