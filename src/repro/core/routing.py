"""MoBA routing: block centroids, causal top-k selection, varlen layout.

Shapes convention (single batch*head slice unless noted):
  q:      (N, d)     queries
  k:      (N, d)     (possibly key-conv'd) keys
  n_blocks = ceil(N / B)

Selection semantics (faithful to the paper / Lu et al.):
  * score of block j for query t is  s_j = q_t · k̃_j  (no 1/sqrt(d))
  * blocks strictly in the future of t are masked out
  * the query's own block is always selected and counts toward top-k
    (this is what makes k/n the exact attended fraction: 7/8 sparsity for
    (B,k) ∈ {(512,2),(256,4),(128,8)} at N=8192)
  * early queries with fewer than k valid blocks select all valid ones;
    the empty slots carry the sentinel block id ``n_blocks``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
POS_INF = 1e30


def pad_to_blocks(x: jax.Array, block_size: int, axis: int = 0) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % block_size
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def block_centroids(k: jax.Array, block_size: int,
                    kv_len: jax.Array | None = None) -> jax.Array:
    """Mean-pool keys into block centroids.

    k: (..., N, d) -> (..., n_blocks, d).  If ``kv_len`` is given (decode
    with a partially-filled cache) positions >= kv_len are excluded from
    the mean.
    """
    *lead, n, d = k.shape
    k = pad_to_blocks(k, block_size, axis=-2)
    nb = k.shape[-2] // block_size
    kb = k.reshape(*lead, nb, block_size, d).astype(jnp.float32)
    if kv_len is None:
        denom = jnp.minimum(
            jnp.maximum(n - jnp.arange(nb) * block_size, 1), block_size
        ).astype(jnp.float32)
        valid = (jnp.arange(nb)[:, None] * block_size
                 + jnp.arange(block_size)[None, :]) < n
        kb = kb * valid[..., None]
        out = kb.sum(-2) / denom[..., None]
    else:
        pos = (jnp.arange(nb)[:, None] * block_size
               + jnp.arange(block_size)[None, :])
        valid = pos < kv_len
        denom = jnp.maximum(valid.sum(-1), 1).astype(jnp.float32)
        kb = kb * valid[..., None]
        out = kb.sum(-2) / denom[..., None]
    return out.astype(k.dtype)


def routing_scores(q: jax.Array, centroids: jax.Array) -> jax.Array:
    """q: (..., Nq, d), centroids: (..., nb, d) -> scores (..., Nq, nb)."""
    return jnp.einsum("...qd,...bd->...qb", q.astype(jnp.float32),
                      centroids.astype(jnp.float32))


def select_blocks(scores: jax.Array, top_k: int, block_size: int,
                  q_positions: jax.Array, causal: bool = True,
                  head_top_k: jax.Array | None = None) -> jax.Array:
    """Top-k block selection with causal masking + forced current block.

    scores: (..., Nq, nb); q_positions: (Nq,) absolute token positions.
    Returns int32 (..., Nq, k) of selected block ids, sentinel ``nb`` for
    empty slots.  Current block (if causal) is forced via +inf so it always
    occupies a slot — faithful to MoBA's accounting.

    ``head_top_k`` (optional int32, broadcastable against the leading
    dims of ``scores``, values in [1, top_k]) truncates each head's
    selection to its own budget: slots ranked >= head_top_k become
    sentinels.  ``top_k`` output slots are score-sorted descending with
    the own block forced first, so keeping the first ``head_top_k`` slots
    is exactly per-head top-k at static shapes (DESIGN.md §8).
    """
    nb = scores.shape[-1]
    own = q_positions // block_size  # (Nq,)
    blk = jnp.arange(nb)
    if causal:
        future = blk[None, :] > own[:, None]          # (Nq, nb)
        is_own = blk[None, :] == own[:, None]
        masked = jnp.where(future, NEG_INF, scores)
        masked = jnp.where(is_own, POS_INF, masked)
    else:
        masked = scores
    kk = min(top_k, nb)
    top_scores, top_idx = jax.lax.top_k(masked, kk)
    # slots whose score is NEG_INF are invalid -> sentinel
    top_idx = jnp.where(top_scores <= NEG_INF / 2, nb, top_idx)
    if kk < top_k:  # fewer blocks than k: pad with sentinels
        pad = jnp.full(top_idx.shape[:-1] + (top_k - kk,), nb,
                       top_idx.dtype)
        top_idx = jnp.concatenate([top_idx, pad], axis=-1)
    if head_top_k is not None:
        keep = jnp.arange(top_k) < head_top_k[..., None, None]
        top_idx = jnp.where(keep, top_idx, nb)
    return top_idx.astype(jnp.int32)


def selection_mask(top_idx: jax.Array, nb: int) -> jax.Array:
    """(..., Nq, k) block ids -> boolean (..., Nq, nb) selection mask."""
    onehot = jax.nn.one_hot(top_idx, nb + 1, dtype=jnp.bool_)
    return onehot.any(axis=-2)[..., :nb]


class VarlenLayout(NamedTuple):
    """Key-block-major padded varlen layout (paper Alg. 4, TPU-native).

    With Nq queries each selecting k blocks there are exactly Nq*k
    (query, block) pairs.  We sort pairs by block id (stable → query order
    preserved inside a block), then pad each block's run to a multiple of
    the physical tile Tq so every tile maps to exactly one key block.

    All shapes are static: capacity L = Nq*k + nb*Tq upper-bounds any
    padding outcome (each of nb blocks wastes < Tq slots; sentinel pairs
    are parked in the trailing region).
    """

    q_index: jax.Array      # (L,) int32: query position per slot, -1 = pad
    slot_block: jax.Array   # (L,) int32: block id per slot, nb = pad
    tile_block: jax.Array   # (L/Tq,) int32: block id per tile, nb = inactive
    pair_slot: jax.Array    # (Nq, k) int32: slot index of each pair (for the
                            # inverse scatter when merging partials)


def build_varlen_layout(top_idx: jax.Array, nq: int, nb: int,
                        tile: int) -> VarlenLayout:
    """top_idx: (Nq, k) selected block ids (sentinel nb). Static-shape,
    fully-jittable construction of the key-block-major layout."""
    k = top_idx.shape[-1]
    flat_block = top_idx.reshape(-1)                       # (Nq*k,)
    flat_q = jnp.repeat(jnp.arange(nq, dtype=jnp.int32), k)

    # stable sort by block id
    order = jnp.argsort(flat_block, stable=True)
    sb = flat_block[order]
    sq = flat_q[order]

    counts = jnp.bincount(flat_block, length=nb + 1)       # (nb+1,)
    padded_counts = ((counts + tile - 1) // tile) * tile
    # sentinel pairs live in the trailing region; give them whatever space
    # remains so slot indices stay in-bounds.
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(padded_counts[:-1]).astype(jnp.int32)])
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts[:-1]).astype(jnp.int32)])

    capacity = nq * k + nb * tile
    rank = jnp.arange(sb.shape[0], dtype=jnp.int32) - offsets[sb]
    slot = starts[sb] + rank                               # (Nq*k,)

    # sentinel pairs are parked in the trailing region with q_index -1 so
    # they are masked exactly like padding.
    q_index = jnp.full((capacity,), -1, jnp.int32).at[slot].set(
        jnp.where(sb == nb, -1, sq))
    slot_block = jnp.full((capacity,), nb, jnp.int32).at[slot].set(sb)
    # Every tile of an active block's run starts with a real slot (padding
    # sits at the run's tail and runs are tile-multiples), so the first
    # slot's block id identifies the tile; nb marks inactive tiles.
    first = slot_block.reshape(-1, tile)[:, 0]
    tile_block = jnp.where(first < nb, first, nb).astype(jnp.int32)
    pair_slot = jnp.zeros((nq * k,), jnp.int32).at[order].set(slot)
    return VarlenLayout(q_index, slot_block, tile_block,
                        pair_slot.reshape(nq, k))


def layout_capacity(nq: int, k: int, nb: int, tile: int) -> int:
    return nq * k + nb * tile
