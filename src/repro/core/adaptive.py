"""SNR-guided adaptive routing: per-(layer, head) top_k from measured SNR.

The paper's statistical model (``core/snr.py``) says block retrieval is
governed by SNR = Δμ_eff·sqrt(d/2B) and that reliable top-k retrieval
among n blocks needs SNR > Φ⁻¹(1 − k/n) (App. A.4).  The serving stack
historically ran one static ``top_k`` for every layer and head; this
module turns the SNR model into a serve-time policy:

  1. **Calibration** (:func:`calibrate_profile`): run a calibration batch
     through the model eagerly with a routing-score capture hook
     (``core.moba`` sink), estimate each (layer, head)'s retrieval margin
     — the gap between the best non-own block score and the noise-block
     distribution, in noise-σ units — and average it into a measured SNR
     per (layer slot, group, kv head, query head).
  2. **Inversion** (:func:`choose_top_k`): pick the smallest ``top_k``
     whose App.-A.4 bound the measured SNR clears with a
     Φ⁻¹(1 − p_fail) safety margin; heads whose routing signal is weak
     keep the static ``k_max``.  Adaptive routing only ever *reduces*
     top_k, so pool shapes and kernel grids stay static.
  3. **Artifact** (:class:`RoutingProfile`): the per-head table is
     serialized to JSON so a profile calibrated once can be shipped,
     loaded by any engine (``route_policy="profile:<path>"``), and
     replayed bit-identically — routing decisions come from the profile,
     never from recomputed serve-time state.

At serve time the profile becomes a ``route_map`` of per-layer-slot
(n_groups, H) int32 arrays threaded through the model scan; every paged
routing path (`core.moba`, both Pallas decode grids, chunked and fresh
prefill) truncates its score-sorted static top-k to the head's budget —
see ``head_top_k`` in `core.moba._truncate_head_topk`.  DESIGN.md §8.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.snr import _norm_ppf, required_snr

# fewer causal noise blocks than this and the noise-σ estimate is
# meaningless — the head keeps the static top_k
MIN_NOISE_BLOCKS = 3


def parse_route_policy(policy: str) -> Tuple[str, Optional[object]]:
    """``"static" | "snr:pfail=P" | "profile:PATH"`` → (mode, arg).

    Raises ValueError on anything else (engines wrap it into their
    admission-time :class:`UnsupportedFeatureError`).
    """
    policy = (policy or "static").strip()
    if policy == "static":
        return "static", None
    mode, _, arg = policy.partition(":")
    if mode == "snr":
        if not arg.startswith("pfail="):
            raise ValueError(
                f"route policy {policy!r}: snr mode takes pfail=P "
                f"(e.g. 'snr:pfail=0.01')")
        try:
            pfail = float(arg[len("pfail="):])
        except ValueError:
            raise ValueError(
                f"route policy {policy!r}: pfail must be a float") from None
        if not 0.0 < pfail < 0.5:
            raise ValueError(
                f"route policy {policy!r}: pfail must be in (0, 0.5)")
        return "snr", pfail
    if mode == "profile":
        if not arg:
            raise ValueError(
                f"route policy {policy!r}: profile mode takes a path "
                f"(e.g. 'profile:routing_profile.json')")
        return "profile", arg
    raise ValueError(
        f"unknown route policy {policy!r}; expected 'static', "
        f"'snr:pfail=P' or 'profile:PATH'")


# -------------------------------------------------------------- score sink
@contextlib.contextmanager
def capture_routing_scores():
    """Context manager: while active, every `core.moba.moba_selection`
    call appends ``(scores (B,Hkv,G,Nq,nb) fp32, q_positions (Nq,))`` to
    the yielded list.  Calibration runs the model *eagerly* (unjitted,
    ``unroll=True``) so captures are concrete arrays in layer order:
    group-major, pattern slots inside each group."""
    from repro.core import moba as M

    captured: List[tuple] = []
    prev = M._score_sink
    M._score_sink = captured.append
    try:
        yield captured
    finally:
        M._score_sink = prev


def estimate_head_snr(scores, q_positions, block_size: int) -> np.ndarray:
    """Measured per-head routing SNR from one layer's routing scores.

    scores: (B, Hkv, G, Nq, nb) centroid scores; q_positions: (Nq,).
    For every query in the *last* own-block (the most context any query
    sees), the best non-own causal block plays the signal and the
    remaining causal blocks the noise: the margin (top1 − μ_noise)/σ_noise
    is exactly the quantity App. A.4's Φ⁻¹(1 − k/n) bound is stated in.
    Averaged over batch and those queries → (Hkv, G) float64.  Heads with
    fewer than ``MIN_NOISE_BLOCKS`` noise blocks report 0 (never adapted).
    """
    s = np.asarray(scores, np.float64)
    pos = np.asarray(q_positions).astype(np.int64).reshape(-1)
    b, hkv, g, nq, nb = s.shape
    own_last = int(pos[-1]) // block_size
    n_noise = own_last            # causal non-own blocks: 0 .. own_last-1
    if n_noise < MIN_NOISE_BLOCKS + 1:
        return np.zeros((hkv, g))
    ts = [t for t in range(nq) if int(pos[t]) // block_size == own_last]
    rows = s[:, :, :, ts, :own_last]            # (B,Hkv,G,T,n_noise)
    top1 = rows.max(axis=-1)
    total = rows.sum(axis=-1)
    sq = (rows ** 2).sum(axis=-1)
    mean_rest = (total - top1) / (n_noise - 1)
    var_rest = np.maximum(
        (sq - top1 ** 2) / (n_noise - 1) - mean_rest ** 2, 1e-12)
    snr = (top1 - mean_rest) / np.sqrt(var_rest)
    return snr.mean(axis=(0, -1))               # (Hkv, G)


def choose_top_k(snr_hat, num_blocks: int, k_max: int,
                 pfail: float) -> np.ndarray:
    """Smallest per-head top_k whose required SNR (App. A.4) the measured
    SNR clears with a Φ⁻¹(1 − pfail) margin; ``k_max`` where none does.

    snr_hat: any-shape array of measured SNRs → same-shape int32 in
    [1, k_max].  Adaptive routing only ever reduces top_k — never above
    the static budget — so downstream shapes stay static.
    """
    if k_max < 1:
        raise ValueError(f"k_max must be >= 1, got {k_max}")
    z = _norm_ppf(1.0 - pfail)
    snr = np.asarray(snr_hat, np.float64)
    k = np.full(snr.shape, k_max, np.int32)
    for cand in range(k_max - 1, 0, -1):
        # k >= n retrieves everything: the bound is vacuous (need -inf)
        need = (required_snr(num_blocks, cand) + z
                if cand < num_blocks else -np.inf)
        k = np.where(snr >= need, np.int32(cand), k)
    # select_blocks pins the query's own page at rank 0 (POS_INF), so a
    # budget of k leaves k-1 score-retrieval slots; reserve one for it.
    return np.clip(k + 1, 1, k_max).astype(np.int32)


# ----------------------------------------------------------------- profile
@dataclasses.dataclass
class RoutingProfile:
    """Serialized outcome of a calibration pass.

    ``top_k`` maps each layer-pattern slot (``"slot_i"``, moba slots
    only) to an (n_groups, H) int32 array of per-head budgets, flattened
    query-head order h = hkv·G + g (the `_group_queries` reshape).
    ``snr`` keeps the measured per-head SNRs alongside for inspection.
    """

    pfail: float
    k_max: int
    num_blocks: int
    block_size: int
    top_k: Dict[str, np.ndarray]
    snr: Optional[Dict[str, list]] = None

    def route_map(self) -> Dict[str, np.ndarray]:
        """The serve-time per-slot (n_groups, H) int32 head budgets."""
        return {slot: np.asarray(arr, np.int32)
                for slot, arr in self.top_k.items()}

    @property
    def is_uniform(self) -> bool:
        """True when every head kept the static budget — the profile is
        then a provable routing no-op (pinned by test)."""
        return all(np.all(np.asarray(a) == self.k_max)
                   for a in self.top_k.values())

    def summary(self) -> str:
        ks = np.concatenate([np.asarray(a).reshape(-1)
                             for a in self.top_k.values()])
        return (f"routing profile: pfail={self.pfail} k_max={self.k_max} "
                f"heads={ks.size} top_k min/mean/max "
                f"{ks.min()}/{ks.mean():.2f}/{ks.max()}")

    def save(self, path: str) -> None:
        doc = {"version": 1, "pfail": self.pfail, "k_max": self.k_max,
               "num_blocks": self.num_blocks,
               "block_size": self.block_size,
               "top_k": {s: np.asarray(a, np.int32).tolist()
                         for s, a in sorted(self.top_k.items())}}
        if self.snr is not None:
            doc["snr"] = self.snr
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "RoutingProfile":
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        top_k = {s: np.asarray(a, np.int32)
                 for s, a in doc["top_k"].items()}
        for slot, arr in top_k.items():
            if arr.ndim != 2 or arr.size == 0:
                raise ValueError(
                    f"routing profile {path}: slot {slot!r} table must "
                    f"be (n_groups, H), got shape {arr.shape}")
            if arr.min() < 1 or arr.max() > doc["k_max"]:
                raise ValueError(
                    f"routing profile {path}: slot {slot!r} top_k "
                    f"outside [1, k_max={doc['k_max']}]")
        return cls(pfail=float(doc["pfail"]), k_max=int(doc["k_max"]),
                   num_blocks=int(doc["num_blocks"]),
                   block_size=int(doc["block_size"]), top_k=top_k,
                   snr=doc.get("snr"))

    @classmethod
    def uniform(cls, cfg, k: Optional[int] = None) -> "RoutingProfile":
        """A profile that assigns every head the static budget — the
        identity policy, used by equivalence tests."""
        moba = cfg.attention.moba
        pattern = cfg.layer_pattern
        n_groups = cfg.num_layers // len(pattern)
        kk = moba.top_k if k is None else k
        top_k = {f"slot_{i}": np.full((n_groups, cfg.num_heads), kk,
                                      np.int32)
                 for i, kind in enumerate(pattern) if kind == "moba"}
        return cls(pfail=0.0, k_max=moba.top_k, num_blocks=0,
                   block_size=moba.block_size, top_k=top_k)


def calibrate_profile(cfg, params, pfail: float, num_blocks: int,
                      calib_tokens=None, seed: int = 0) -> RoutingProfile:
    """Measure per-(layer, head) SNR on a calibration batch and invert
    the App.-A.4 bound into a :class:`RoutingProfile`.

    ``num_blocks`` is the serve-time routing universe (the engine passes
    its pages-per-sequence) — the bound is evaluated against the pool a
    decode step actually ranks, not the calibration length.  The forward
    pass runs eagerly on the ``reference`` backend (routing scores are
    selection-semantics-invariant across backends, so the cheapest
    scorer calibrates them all) with the `core.moba` capture sink
    active; captures arrive group-major in slot order, which is how they
    are mapped back onto (slot, group).
    """
    import jax.numpy as jnp

    from repro.models import transformer as T

    moba = cfg.attention.moba
    if moba is None:
        raise ValueError("adaptive routing needs a MoBA attention config")
    pattern = list(cfg.layer_pattern)
    n_groups = cfg.num_layers // len(pattern)
    moba_slots = [i for i, kind in enumerate(pattern) if kind == "moba"]
    if not moba_slots:
        raise ValueError(
            f"adaptive routing needs at least one moba slot in the "
            f"layer pattern, got {pattern}")
    bs = moba.block_size
    if calib_tokens is None:
        # enough context that the last block sees a real noise population
        n_blk = max(MIN_NOISE_BLOCKS + 2, min(8, max(num_blocks, 1)))
        rng = np.random.default_rng(seed)
        calib_tokens = rng.integers(0, cfg.vocab_size, (2, n_blk * bs),
                                    dtype=np.int32)
    with capture_routing_scores() as caps:
        T.lm_apply(params, jnp.asarray(calib_tokens, jnp.int32), cfg,
                   caches=None, backend="reference", unroll=True)
    expect = len(moba_slots) * n_groups
    if len(caps) != expect:
        raise ValueError(
            f"calibration captured {len(caps)} routing-score tensors, "
            f"expected {expect} ({len(moba_slots)} moba slots x "
            f"{n_groups} groups) — was the forward pass jitted?")
    top_k: Dict[str, np.ndarray] = {
        f"slot_{i}": np.full((n_groups, cfg.num_heads), moba.top_k,
                             np.int32) for i in moba_slots}
    snr_out: Dict[str, list] = {f"slot_{i}": [[0.0] * cfg.num_heads
                                              for _ in range(n_groups)]
                                for i in moba_slots}
    for ci, (scores, q_pos) in enumerate(caps):
        gi, si = divmod(ci, len(moba_slots))     # group-major capture order
        slot = f"slot_{moba_slots[si]}"
        snr = estimate_head_snr(scores, q_pos, bs)          # (Hkv, G)
        ks = choose_top_k(snr, num_blocks, moba.top_k, pfail)
        top_k[slot][gi] = ks.reshape(-1)                    # h = hkv*G + g
        snr_out[slot][gi] = [round(float(v), 4)
                             for v in snr.reshape(-1)]
    return RoutingProfile(pfail=pfail, k_max=moba.top_k,
                          num_blocks=num_blocks, block_size=bs,
                          top_k=top_k, snr=snr_out)
