"""Short depthwise causal convolution on keys (paper Appendix B).

``k'_t = k_t + SiLU( sum_{l=0}^{W-1} W_l ⊙ k_{t-l} )``

Depthwise over every key channel (per kv-head, per head-dim), causal
(left-padded), SiLU activation, residual.  Applied to keys *before* both
routing (centroid computation) and attention, so router gradients flow
through it and encourage within-block clustering (raising Δμ_eff).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_key_conv(key: jax.Array, width: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.float32) -> jax.Array:
    """Weights shaped (W, num_kv_heads, head_dim); small init so the
    residual branch starts near identity."""
    w = jax.random.normal(key, (width, num_kv_heads, head_dim), dtype)
    return w * (0.02 / max(1, width))


def apply_key_conv(weights: jax.Array, k: jax.Array) -> jax.Array:
    """Apply depthwise causal conv.

    weights: (W, Hkv, d); k: (..., Hkv, N, d)  ->  same shape as k.

    Implemented as a sum of W shifted copies — W is 3 or 5, so this is a
    handful of cheap vector ops that XLA fuses; no kernel needed.
    """
    width = weights.shape[0]
    conv = jnp.zeros_like(k, dtype=jnp.float32)
    kf = k.astype(jnp.float32)
    for lag in range(width):
        shifted = kf if lag == 0 else jnp.roll(kf, lag, axis=-2)
        if lag > 0:
            # causal: zero the wrapped-around prefix
            n = k.shape[-2]
            mask = (jnp.arange(n) >= lag).astype(kf.dtype)
            shifted = shifted * mask[:, None]
        conv = conv + shifted * weights[lag].astype(jnp.float32)[..., None, :]
    out = kf + jax.nn.silu(conv)
    return out.astype(k.dtype)


def apply_key_conv_with_state(weights: jax.Array, k: jax.Array,
                              state: jax.Array) -> jax.Array:
    """Causal conv over a chunk with carried left context (chunked prefill).

    weights: (W, Hkv, d); k: (B, Hkv, N, d) raw keys of this chunk;
    state: (B, Hkv, W-1, d) the W-1 raw keys immediately before the chunk
    (zeros for a fresh sequence).  Returns conv'd keys, same shape as k.

    With a zero state this is bitwise-identical to :func:`apply_key_conv`
    (term-by-term the same fp32 ops in the same order), which is what
    makes chunked and one-shot prefill conv-equivalent at chunk
    boundaries inside a conv window.
    """
    width = weights.shape[0]
    depth = width - 1
    n = k.shape[-2]
    kf = k.astype(jnp.float32)
    hist = jnp.concatenate([state.astype(jnp.float32), kf], axis=-2)
    conv = jnp.zeros_like(kf)
    for lag in range(width):
        shifted = jax.lax.slice_in_dim(hist, depth - lag, depth - lag + n,
                                       axis=-2)
        conv = conv + shifted * weights[lag].astype(jnp.float32)[..., None, :]
    out = kf + jax.nn.silu(conv)
    return out.astype(k.dtype)


def key_conv_state_init(width: int, batch: int, num_kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16) -> jax.Array:
    """Decode-time ring buffer of the last W-1 raw keys."""
    return jnp.zeros((batch, num_kv_heads, max(width - 1, 0), head_dim), dtype)


def key_conv_state_update(state: jax.Array, k_raw: jax.Array,
                          q_len: jax.Array) -> jax.Array:
    """Advance a ring buffer past a ragged prefill chunk.

    state: (B, Hkv, W-1, d) raw keys before the chunk; k_raw: (B, Hkv, L, d)
    right-padded chunk raw keys with per-row valid length ``q_len`` (B,).
    Returns the raw keys at the W-1 positions immediately before each
    row's new end — rows with q_len 0 keep their state unchanged.
    """
    depth = state.shape[-2]
    if depth == 0:
        return state
    hist = jnp.concatenate([state, k_raw.astype(state.dtype)], axis=-2)
    idx = (q_len[:, None] + jnp.arange(depth))[:, None, :, None]
    return jnp.take_along_axis(hist, idx, axis=-2)


def apply_key_conv_decode(weights: jax.Array, k_new: jax.Array,
                          state: jax.Array):
    """One-step causal conv for decode.

    k_new: (B, Hkv, 1, d); state: (B, Hkv, W-1, d) holding previous raw keys
    (most recent last).  Returns (k_conv, new_state).
    """
    width = weights.shape[0]
    hist = jnp.concatenate([state, k_new], axis=-2)  # (B,Hkv,W,d) raw keys
    kf = hist.astype(jnp.float32)
    # conv at the current position: sum_l W_l * k_{t-l}
    taps = kf[..., ::-1, :][..., :width, :]  # most recent first
    w = weights.astype(jnp.float32)[:, None, :, :].transpose(1, 2, 0, 3)
    conv = jnp.sum(taps * w, axis=-2, keepdims=True)
    out = kf[..., -1:, :] + jax.nn.silu(conv)
    new_state = hist[..., 1:, :] if width > 1 else state
    return out.astype(k_new.dtype), new_state
