"""Attention-backend registry: one seam for every attention implementation.

Implementation choice used to be string-plumbed (``moba_impl`` / ``kind``
branches) through ``core/attention.py``, ``core/moba.py``,
``models/layers.py``, ``models/transformer.py``, ``launch/steps.py`` and
``serving/engine.py``.  This module replaces those branches with a
first-class registry (DESIGN.md §5): an :class:`AttentionBackend` declares
its :class:`Capabilities` (attention kinds × prefill/decode phases ×
dense/paged cache protocols × key-conv), and call sites select by *name +
capability query* via :func:`resolve`.

Registered backends:

  reference     O(N²) masked-softmax oracle (``core/moba.py``)
  xla           pure-XLA gather-and-densify (alias: ``sparse``)
  xla_unrolled  same, unrolled tiles for dry-run FLOP accounting
                (alias: ``sparse_unrolled``)
  flash         Pallas kernels: FlashMoBA prefill + the fused
                scalar-prefetched paged-decode kernel
                (aliases: ``kernel``, ``pallas``)
  sp            context/sequence-parallel MoBA (dense caches only)
  sp_unrolled   same, unrolled (dry-run)
  sharded       multi-host serving seam: per-shard math delegates to an
                inner single-host backend (default ``xla``); the sharded
                engine runs it inside one shard_map over the mesh
                ``data`` axis (``serving/sharded.py``, DESIGN.md §7)

Dense and sliding-window kinds share one implementation across backends
(base-class methods); MoBA is where backends differ.  Paged *prefill* is
deliberately shared too: the ragged reference path is the only
implementation with per-sequence ``kv_len`` masking (DESIGN.md §4).

Run ``python -m repro.core.backends`` to print the capability matrix —
CI uses this as a registry-drift check (every backend must import and
self-validate).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.core.moba import (moba_attention_reference, moba_decode_attention,
                             moba_paged_decode_attention,
                             moba_paged_prefill_attention)
from repro.core.quantization import KV_DTYPES

KINDS = ("dense", "swa", "moba")
PHASES = ("prefill", "decode")
CACHES = ("dense", "paged")


class BackendCapabilityError(ValueError):
    """Requested (backend, kind, phase, cache) combination is unsupported.

    The message names the backends that *do* support the combination, so
    callers (and users reading a traceback) can re-select."""


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can run.  ``caches`` uses 'dense' for both the
    cache-free (training) and dense-KV-cache paths — they share math —
    and 'paged' for the serving engine's block-table pools.

    ``key_conv`` lists the cache protocols under which the backend can
    consume key-conv'd keys.  The conv itself happens in
    ``models/layers.py`` before keys reach any backend — paged caches
    additionally need the engine's per-slot raw-key ring buffer
    (DESIGN.md §4), so a backend declares the protocols whose conv state
    plumbing it is validated against rather than a single bool.

    ``sharded`` declares the backend safe inside the sharded serving
    engine's per-shard ``shard_map`` body (DESIGN.md §7): its math must
    be mesh-free — no collectives, no axis names — because each shard
    runs it on a local pool slice.  ``sp``/``sp_unrolled`` issue their
    own collectives over a mesh axis and so cannot nest.

    ``kv_dtypes`` lists the paged-pool storage dtypes the backend's
    paged paths are validated against (``core/quantization.py``):
    ``int8``/``fp8`` pools carry per-page scale leaves the backend must
    dequantize with.  Default is fp32-only — quantized support is an
    explicit opt-in so an unvalidated backend fails at admission, not
    with silently-garbage attention output.

    ``adaptive_topk`` declares that the backend's paged MoBA paths honor
    per-(layer, head) ``head_top_k`` budgets (SNR-guided adaptive
    routing, DESIGN.md §8).  ``sp``/``sp_unrolled`` run the dense-cache
    context-parallel fallback whose distributed selection has no
    per-head budget plumbing — they stay static."""

    kinds: Tuple[str, ...] = KINDS
    phases: Tuple[str, ...] = PHASES
    caches: Tuple[str, ...] = CACHES
    key_conv: Tuple[str, ...] = CACHES
    sharded: bool = True
    kv_dtypes: Tuple[str, ...] = ("fp32",)
    adaptive_topk: bool = True

    def supports(self, kind: str, phase: str, cache: str = "dense",
                 key_conv: bool = False, sharded: bool = False,
                 kv_dtype: str = "fp32", adaptive: bool = False) -> bool:
        return (kind in self.kinds and phase in self.phases
                and cache in self.caches
                and (not key_conv or cache in self.key_conv)
                and (not sharded or self.sharded)
                and kv_dtype in self.kv_dtypes
                and (not adaptive or self.adaptive_topk))


class AttentionBackend:
    """Protocol + shared implementations.

    Subclasses override the ``moba_*`` hooks; dense/swa attention and the
    paged-prefill path are shared (see module docstring).  ``**opts``
    carries backend-specific hints (e.g. ``interpret`` for Pallas) that
    other backends ignore.
    """

    name: str = ""
    aliases: Tuple[str, ...] = ()
    capabilities: Capabilities = Capabilities()

    # ------------------------------------------------------------ helpers
    @staticmethod
    def _window(cfg: AttentionConfig, kind: str) -> int:
        return cfg.window if kind == "swa" else 0

    # ------------------------------------------- full-sequence / dense KV
    def prefill(self, cfg: AttentionConfig, kind: str, q, k, v, *,
                q_positions=None, kv_len=None, causal: bool = True,
                **opts) -> jax.Array:
        """Multi-token attention: training, prefill, or cached prefill
        (``kv_len`` marks the valid prefix of a dense cache)."""
        if kind == "moba":
            return self.moba_prefill(cfg, q, k, v, q_positions=q_positions,
                                     **opts)
        from repro.core.attention import dense_attention
        return dense_attention(q, k, v, causal=causal,
                               q_positions=q_positions, kv_len=kv_len,
                               window=self._window(cfg, kind),
                               scale=cfg.scale)

    def decode(self, cfg: AttentionConfig, kind: str, q, k, v, kv_len, *,
               centroids=None, q_positions=None, **opts) -> jax.Array:
        """Single-token attention against a dense cache of which the first
        ``kv_len`` positions are valid."""
        if kind == "moba":
            return self.moba_decode(cfg, q, k, v, kv_len,
                                    centroids=centroids, **opts)
        from repro.core.attention import dense_attention
        return dense_attention(q, k, v, causal=True,
                               q_positions=q_positions, kv_len=kv_len,
                               window=self._window(cfg, kind),
                               scale=cfg.scale)

    # --------------------------------------------------------- paged KV
    def paged_prefill(self, cfg: AttentionConfig, kind: str, q, k, v, *,
                      post_len, positions, **opts) -> jax.Array:
        """Ragged fresh prefill (right-padded rows; ``post_len`` is the
        per-sequence valid length after this step).  Shared across
        backends: the reference path is the only implementation with
        per-sequence kv_len masking, and routing a padded row is harmless
        (DESIGN.md §4)."""
        if kind == "moba":
            return moba_attention_reference(
                q, k, v, cfg.moba, q_positions=positions,
                kv_len=post_len[:, None, None, None], scale=cfg.scale,
                head_top_k=opts.get("head_top_k"))
        from repro.core.attention import dense_attention
        return dense_attention(q, k, v, causal=True, q_positions=positions,
                               kv_len=post_len,
                               window=self._window(cfg, kind),
                               scale=cfg.scale)

    def paged_chunk_prefill(self, cfg: AttentionConfig, kind: str, q, cache,
                            block_table, kv_len, q_len,
                            **opts) -> jax.Array:
        """Chunked prefill: multi-token attention for a ragged chunk whose
        K/V (and every earlier chunk's) are already appended to ``cache``.
        ``kv_len`` is the per-sequence pre-chunk length, ``q_len`` the
        chunk's valid tokens, so query i,j sits at position
        ``kv_len[i] + j``.  Shared across backends like
        :meth:`paged_prefill`: MoBA routes the chunk's queries on the
        per-page centroid cache (bitwise the same page selection as
        one-shot prefill — complete pages have identical centroids and
        partial pages are only ever force-included, DESIGN.md §6) and the
        dense/swa kinds densify through the block table."""
        from repro.serving import paged_cache as PC
        if kind == "moba":
            return moba_paged_prefill_attention(
                q, cache["pages_k"], cache["pages_v"], cache["centroids"],
                block_table, kv_len, q_len, cfg.moba, scale=cfg.scale,
                scales_k=cache.get("scales_k"),
                scales_v=cache.get("scales_v"),
                head_top_k=opts.get("head_top_k"))
        kf, vf = PC.paged_gather_kv(cache, block_table)
        from repro.core.attention import dense_attention
        return dense_attention(q, kf, vf, causal=True,
                               q_positions=kv_len[:, None]
                               + jnp.arange(q.shape[2]),
                               kv_len=kv_len + q_len,
                               window=self._window(cfg, kind),
                               scale=cfg.scale)

    def paged_decode(self, cfg: AttentionConfig, kind: str, q, cache,
                     block_table, kv_len, *, positions=None,
                     **opts) -> jax.Array:
        """Single-token attention against a paged pool through the block
        table.  ``kv_len`` is the post-append per-sequence length.  SWA
        gathers only the ~ceil(window/page_size)+1 pages inside the
        window; dense necessarily densifies the table."""
        from repro.serving import paged_cache as PC
        if kind == "moba":
            return self.moba_paged_decode(cfg, q, cache, block_table,
                                          kv_len, **opts)
        if kind == "swa":
            return PC.swa_windowed_decode_attention(
                q, cache, block_table, kv_len, cfg.window, scale=cfg.scale)
        kf, vf = PC.paged_gather_kv(cache, block_table)
        from repro.core.attention import dense_attention
        return dense_attention(q, kf, vf, causal=True,
                               q_positions=positions, kv_len=kv_len,
                               scale=cfg.scale)

    # ------------------------------------------------ MoBA-specific hooks
    def moba_prefill(self, cfg: AttentionConfig, q, k, v, *,
                     q_positions=None, **opts) -> jax.Array:
        raise NotImplementedError(f"{self.name}: moba prefill")

    def moba_decode(self, cfg: AttentionConfig, q, k, v, kv_len, *,
                    centroids=None, **opts) -> jax.Array:
        # block routing is implementation-independent at decode; the XLA
        # gather path is the shared dense-cache implementation
        return moba_decode_attention(q, k, v, kv_len, cfg.moba,
                                     scale=cfg.scale, centroids=centroids)

    def moba_paged_decode(self, cfg: AttentionConfig, q, cache, block_table,
                          kv_len, **opts) -> jax.Array:
        return moba_paged_decode_attention(
            q, cache["pages_k"], cache["pages_v"], cache["centroids"],
            block_table, kv_len, cfg.moba, scale=cfg.scale,
            scales_k=cache.get("scales_k"), scales_v=cache.get("scales_v"),
            head_top_k=opts.get("head_top_k"))


# ---------------------------------------------------------------- backends
class ReferenceBackend(AttentionBackend):
    """O(N²) masked-softmax oracle — the correctness anchor."""

    name = "reference"

    def moba_prefill(self, cfg, q, k, v, *, q_positions=None, **opts):
        return moba_attention_reference(q, k, v, cfg.moba,
                                        q_positions=q_positions,
                                        scale=cfg.scale)


class XLABackend(AttentionBackend):
    """Pure-XLA gather-and-densify (production fallback, differentiable)."""

    name = "xla"
    aliases = ("sparse",)
    capabilities = Capabilities(kv_dtypes=KV_DTYPES)
    use_scan = True

    def moba_prefill(self, cfg, q, k, v, *, q_positions=None, **opts):
        from repro.kernels import ref
        return ref.moba_sparse_xla(q, k, v, cfg.moba,
                                   q_positions=q_positions, scale=cfg.scale,
                                   use_scan=self.use_scan)


class XLAUnrolledBackend(XLABackend):
    """Unrolled tiles: XLA cost_analysis counts scan bodies once — the
    dry-run needs this form for faithful FLOP accounting."""

    name = "xla_unrolled"
    aliases = ("sparse_unrolled",)
    use_scan = False


class FlashBackend(AttentionBackend):
    """Pallas kernel path: FlashMoBA prefill (DESIGN.md §2) + the fused
    scalar-prefetched paged-decode kernel (DESIGN.md §5).  Dense-cache
    decode shares the XLA gather (routing math is identical; the kernel
    pays off where the block table gives page-granular indirection)."""

    name = "flash"
    aliases = ("kernel", "pallas")
    capabilities = Capabilities(kv_dtypes=KV_DTYPES)
    # interpret vs compiled Pallas lowering.  None defers to
    # `kernels.runtime.resolve_interpret`: the REPRO_PALLAS_INTERPRET
    # env var if set, else compiled on TPU hosts / interpret everywhere
    # else — CPU CI and a TPU pod run the same code with no edits.
    # Override per call via opts, per process via
    # `backends.get("flash").interpret = False`, or per CLI via
    # `--attn-backend flash:compiled` (see :func:`parse_backend_spec`).
    interpret: Optional[bool] = None
    # paged-decode grid: "grouped" = (B·Hkv, U) MXU tiles (default),
    # "flat" = legacy (B·H, top_k) per-query-head VPU products
    decode_grid: str = "grouped"
    # training/prefill grid: "grouped" = grouped-GQA topk + kb-tiled
    # fwd/bwd MXU grids (default), "flat" = legacy seed-era grids kept
    # selectable for bisection (DESIGN.md §2)
    train_grid: str = "grouped"
    # K/V streaming granularity of the kb-tiled fwd/bwd grids;
    # 0 = auto (min(block_size, 128)).  Set via `flash:kb_tile=N`.
    kb_tile: int = 0

    def _interpret(self, opts) -> bool:
        from repro.kernels.runtime import resolve_interpret
        return resolve_interpret(opts.get("interpret", self.interpret))

    def moba_prefill(self, cfg, q, k, v, *, q_positions=None, **opts):
        from repro.kernels import ops
        return ops.flash_moba(q, k, v, cfg.moba, q_positions=q_positions,
                              scale=cfg.scale,
                              kb_tile=opts.get("kb_tile", self.kb_tile),
                              grid=opts.get("grid", self.train_grid),
                              interpret=self._interpret(opts))

    def moba_paged_decode(self, cfg, q, cache, block_table, kv_len, **opts):
        from repro.kernels import moba_decode
        return moba_decode.moba_paged_decode_pallas(
            q, cache["pages_k"], cache["pages_v"], cache["centroids"],
            block_table, kv_len, cfg.moba, scale=cfg.scale,
            interpret=self._interpret(opts),
            grid=opts.get("grid", self.decode_grid),
            scales_k=cache.get("scales_k"), scales_v=cache.get("scales_v"),
            head_top_k=opts.get("head_top_k"))


class SPBackend(AttentionBackend):
    """Sequence/context-parallel MoBA (distributed/moba_sp.py).  Dense
    caches only, and never inside the sharded engine's shard_map (it
    issues its own collectives over a mesh axis); the sharded engine
    instead uses it *around* the paged path as the context-parallel
    fallback for requests longer than one shard's pool (DESIGN.md §7)."""

    name = "sp"
    capabilities = Capabilities(caches=("dense",), key_conv=("dense",),
                                sharded=False, adaptive_topk=False)
    use_scan = True

    def moba_prefill(self, cfg, q, k, v, *, q_positions=None, **opts):
        from repro.distributed.moba_sp import moba_attention_sp
        return moba_attention_sp(q, k, v, cfg.moba, scale=cfg.scale,
                                 q_positions=q_positions,
                                 use_scan=self.use_scan)

    def moba_decode(self, cfg, q, k, v, kv_len, *, centroids=None, **opts):
        from repro.distributed.moba_sp import moba_decode_cp
        return moba_decode_cp(q, k, v, kv_len, cfg.moba, scale=cfg.scale,
                              centroids=centroids)


class SPUnrolledBackend(SPBackend):
    name = "sp_unrolled"
    use_scan = False


class ShardedBackend(AttentionBackend):
    """Multi-host serving backend (DESIGN.md §7): the name the sharded
    engine's admission query resolves.  Per-shard attention math is
    delegated to a mesh-free ``inner`` backend (default ``xla``) — the
    sharding itself lives in the engine's ``shard_map``-wrapped step
    functions (``launch/steps.py``), not in the attention math, which is
    exactly why a shard's tokens are bit-identical to a single-host
    engine's.  Usable on a single host too (it is just ``inner`` then).
    """

    name = "sharded"
    capabilities = Capabilities(kv_dtypes=KV_DTYPES)
    inner = "xla"

    def _delegate(self, opts) -> AttentionBackend:
        return get(opts.pop("inner", None) or self.inner)

    def moba_prefill(self, cfg, q, k, v, *, q_positions=None, **opts):
        return self._delegate(opts).moba_prefill(
            cfg, q, k, v, q_positions=q_positions, **opts)

    def moba_decode(self, cfg, q, k, v, kv_len, *, centroids=None, **opts):
        return self._delegate(opts).moba_decode(
            cfg, q, k, v, kv_len, centroids=centroids, **opts)

    def moba_paged_decode(self, cfg, q, cache, block_table, kv_len, **opts):
        return self._delegate(opts).moba_paged_decode(
            cfg, q, cache, block_table, kv_len, **opts)


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, AttentionBackend] = {}
_ALIASES: Dict[str, str] = {}


def register(backend: AttentionBackend) -> AttentionBackend:
    assert backend.name, "backend must set a name"
    for key in (backend.name,) + backend.aliases:
        taken = _ALIASES.get(key)
        assert taken is None or taken == backend.name, (
            f"backend name/alias {key!r} already registered for {taken!r}")
    _REGISTRY[backend.name] = backend
    for key in (backend.name,) + backend.aliases:
        _ALIASES[key] = backend.name
    return backend


def names() -> Tuple[str, ...]:
    """Canonical backend names (aliases excluded), registration order."""
    return tuple(_REGISTRY)


def get(name: str) -> AttentionBackend:
    canonical = _ALIASES.get(name)
    if canonical is None:
        raise BackendCapabilityError(
            f"unknown attention backend {name!r}; registered: "
            f"{sorted(_ALIASES)}")
    return _REGISTRY[canonical]


def parse_backend_spec(spec: str) -> str:
    """``name[:option,...]`` → registered backend name, applying each
    option to the backend instance — the one string every
    CLI/EngineConfig surface accepts (``--attn-backend flash:compiled``,
    ``--attn-backend flash:flat,kb_tile=64``).

    Options: ``interpret`` / ``compiled`` toggle the Pallas lowering on
    backends that expose an ``interpret`` attribute (process-wide, like
    setting ``backends.get(name).interpret`` directly); ``grouped`` /
    ``flat`` select the kernel grids — both the paged-decode grid
    (``decode_grid``) and the training/prefill grid (``train_grid``) on
    backends carrying those attributes; ``kb_tile=N`` sets the K/V
    streaming granularity of the kb-tiled training grids (0 = auto).
    Unknown names or options raise :class:`BackendCapabilityError`.
    """
    name, _, optstr = spec.partition(":")
    if not optstr:
        return name
    be = get(name)
    for opt in optstr.split(","):
        opt = opt.strip()
        if opt in ("interpret", "compiled"):
            if not hasattr(be, "interpret"):
                raise BackendCapabilityError(
                    f"backend {be.name!r} has no interpret/compiled toggle "
                    f"(only Pallas backends do); got {spec!r}")
            be.interpret = opt == "interpret"
        elif opt in ("grouped", "flat"):
            if not hasattr(be, "decode_grid") \
                    and not hasattr(be, "train_grid"):
                raise BackendCapabilityError(
                    f"backend {be.name!r} has no decode-grid option; "
                    f"got {spec!r}")
            if hasattr(be, "decode_grid"):
                be.decode_grid = opt
            if hasattr(be, "train_grid"):
                be.train_grid = opt
        elif opt.startswith("kb_tile="):
            if not hasattr(be, "kb_tile"):
                raise BackendCapabilityError(
                    f"backend {be.name!r} has no kb_tile option (only the "
                    f"kb-tiled Pallas training grids do); got {spec!r}")
            try:
                be.kb_tile = int(opt.split("=", 1)[1])
            except ValueError:
                raise BackendCapabilityError(
                    f"unknown backend option {opt!r} in {spec!r}: "
                    f"kb_tile takes an integer (0 = auto)") from None
        else:
            raise BackendCapabilityError(
                f"unknown backend option {opt!r} in {spec!r}; expected "
                f"interpret | compiled | grouped | flat | kb_tile=N")
    return name


def resolve_backend_spec(spec: Optional[str], *,
                         default: str = "reference") -> str:
    """THE backend-spec resolver every surface shares — ``Engine``,
    ``ShardedEngine``, and the train/serve CLIs all funnel through this
    one function so their spec handling cannot drift.

    An empty/None ``spec`` falls back to ``default`` (each surface's
    documented default backend); otherwise the ``name[:option,...]``
    string is parsed by :func:`parse_backend_spec` (applying options to
    the registry instance) and the name is validated eagerly against
    the registry, so an unknown backend fails at config time with a
    :class:`BackendCapabilityError` instead of inside the first jitted
    step.  Returns the backend name as given (aliases preserved —
    ``get`` canonicalizes at use)."""
    spec = (spec or "").strip() or default
    name = parse_backend_spec(spec)
    get(name)
    return name


def resolve(name: str, *, kind: str, phase: str, cache: str = "dense",
            key_conv: bool = False, sharded: bool = False,
            kv_dtype: str = "fp32", adaptive: bool = False
            ) -> AttentionBackend:
    """Name + capability query: the single entry point call sites use.
    ``sharded=True`` additionally demands mesh-free per-shard math (the
    sharded serving engine's admission query, DESIGN.md §7);
    ``kv_dtype`` of ``int8``/``fp8`` demands quantized-pool support
    (per-page scale dequantization in every paged path);
    ``adaptive=True`` demands per-head ``head_top_k`` routing support
    (SNR-guided adaptive routing, DESIGN.md §8)."""
    be = get(name)
    if not be.capabilities.supports(kind, phase, cache, key_conv, sharded,
                                    kv_dtype, adaptive):
        able = [b.name for b in _REGISTRY.values()
                if b.capabilities.supports(kind, phase, cache, key_conv,
                                           sharded, kv_dtype, adaptive)]
        raise BackendCapabilityError(
            f"backend {be.name!r} does not support kind={kind!r} "
            f"phase={phase!r} cache={cache!r} key_conv={key_conv} "
            f"sharded={sharded} kv_dtype={kv_dtype!r} adaptive={adaptive}; "
            f"backends that do: {able}")
    return be


for _be in (ReferenceBackend(), XLABackend(), XLAUnrolledBackend(),
            FlashBackend(), SPBackend(), SPUnrolledBackend(),
            ShardedBackend()):
    register(_be)


def capability_matrix() -> str:
    """Human-readable support table (also the CI registry-drift check)."""
    lines = [f"{'backend':<14}{'aliases':<22}{'kinds':<18}"
             f"{'phases':<18}{'caches':<14}{'key_conv':<14}"
             f"{'sharded':<10}{'adaptive':<10}kv_dtypes"]
    for be in _REGISTRY.values():
        c = be.capabilities
        lines.append(f"{be.name:<14}{','.join(be.aliases) or '-':<22}"
                     f"{','.join(c.kinds):<18}{','.join(c.phases):<18}"
                     f"{','.join(c.caches):<14}{','.join(c.key_conv):<14}"
                     f"{'yes' if c.sharded else '-':<10}"
                     f"{'yes' if c.adaptive_topk else '-':<10}"
                     f"{','.join(c.kv_dtypes)}")
    return "\n".join(lines)


_DOCS_BEGIN = "<!-- capability-matrix:begin (generated) -->"
_DOCS_END = "<!-- capability-matrix:end -->"


def sync_docs(path: str) -> bool:
    """Rewrite the generated capability-matrix block of ``path`` (between
    the begin/end markers).  Returns True when the file changed — CI runs
    this and fails on a dirty diff, so docs/backends.md can never drift
    from the registry."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    b, e = text.index(_DOCS_BEGIN), text.index(_DOCS_END)
    block = (f"{_DOCS_BEGIN}\n```\n{capability_matrix()}\n```\n")
    new = text[:b] + block + text[e:]
    if new == text:
        return False
    with open(path, "w", encoding="utf-8") as f:
        f.write(new)
    return True


def _main(argv=None) -> int:
    import sys
    argv = sys.argv[1:] if argv is None else argv
    # drift check: every backend constructs, every alias resolves to a
    # registered backend, and at least one backend covers each
    # (kind, phase, cache) cell that the serving engine needs.
    assert names(), "registry is empty"
    for alias, canonical in _ALIASES.items():
        assert get(alias) is _REGISTRY[canonical], alias
    for kind in KINDS:
        for phase in PHASES:
            for cache in CACHES:
                able = [b for b in _REGISTRY.values()
                        if b.capabilities.supports(kind, phase, cache)]
                assert able, f"no backend covers {kind}/{phase}/{cache}"
    if argv and argv[0] == "--sync-docs":
        path = argv[1] if len(argv) > 1 else "docs/backends.md"
        changed = sync_docs(path)
        print(f"{path}: {'updated' if changed else 'up to date'}")
        return 0
    print(capability_matrix())
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
