"""Mixture of Block Attention — pure-JAX reference + public entry point.

The reference path materializes the N×N mask and is the correctness oracle
for the Pallas kernels (`repro.kernels`).  The public `moba_attention`
selects an implementation from the backend registry (`core.backends`,
DESIGN.md §5).

Shapes: q (B, H, Nq, d); k, v (B, Hkv, N, d) with H % Hkv == 0 (GQA —
query heads grouped onto kv heads, paper App. C: no KV duplication, only
index remapping; here expressed via reshape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import routing
from repro.core.key_conv import apply_key_conv

NEG_INF = routing.NEG_INF

# Calibration hook (core.adaptive.capture_routing_scores): when set to a
# callable, moba_selection feeds it (scores, q_positions) per call.  Only
# meaningful for eager (unjitted) passes — under jit the hook would see
# tracers, so the calibration pass always runs eagerly.
_score_sink = None


def _group_queries(q: jax.Array, num_kv_heads: int) -> jax.Array:
    b, h, n, d = q.shape
    g = h // num_kv_heads
    return q.reshape(b, num_kv_heads, g, n, d)


def _truncate_head_topk(idx: jax.Array, sel_valid: jax.Array,
                        head_top_k: Optional[jax.Array]):
    """Truncate a score-sorted (B, Hkv, G, L, k) page selection to
    per-head budgets.  ``head_top_k``: (Hkv, G) int32 in [1, k]; slots
    ranked >= the head's budget become invalid.  Rank 0 is the forced
    own page (POS_INF), so budgets >= 1 always keep it."""
    if head_top_k is None:
        return idx, sel_valid
    keep = jnp.arange(idx.shape[-1]) < head_top_k[..., None, None]
    sel_valid = sel_valid & keep                  # (Hkv,G,1,k) broadcast
    return jnp.where(sel_valid, idx, 0), sel_valid


def moba_selection(q: jax.Array, k: jax.Array, cfg: MoBAConfig,
                   q_positions: Optional[jax.Array] = None,
                   head_top_k: Optional[jax.Array] = None) -> jax.Array:
    """Routing only: returns selected block ids (B, H, Nq, top_k).

    ``k`` must already be key-conv'd if key conv is enabled.
    ``head_top_k``: optional (Hkv, G) int32 per-head budgets in
    [1, top_k]; truncated slots carry the sentinel block id.
    """
    b, hkv, n, d = k.shape
    nq = q.shape[2]
    if q_positions is None:
        q_positions = jnp.arange(nq) + (n - nq)  # suffix alignment (decode)
    cents = routing.block_centroids(k, cfg.block_size)      # (B,Hkv,nb,d)
    qg = _group_queries(q, hkv)                              # (B,Hkv,G,Nq,d)
    scores = jnp.einsum("bhgqd,bhnd->bhgqn", qg.astype(jnp.float32),
                        cents.astype(jnp.float32))
    if _score_sink is not None:
        _score_sink((scores, q_positions))
    sel = routing.select_blocks(scores, cfg.top_k, cfg.block_size,
                                q_positions, causal=cfg.causal,
                                head_top_k=head_top_k)
    return sel.reshape(b, -1, nq, cfg.top_k)


def moba_attention_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                             cfg: MoBAConfig,
                             q_positions: Optional[jax.Array] = None,
                             kv_len: Optional[jax.Array] = None,
                             scale: Optional[float] = None,
                             head_top_k: Optional[jax.Array] = None
                             ) -> jax.Array:
    """Oracle implementation: O(N^2) masked softmax attention where the
    mask is derived from MoBA block selection.

    mask[t, s] = selected[t, block(s)] AND s <= t (causal)   [causal mode]
    mask[t, s] = selected[t, block(s)]                       [bidirectional]
    """
    b, h, nq, d = q.shape
    _, hkv, n, _ = k.shape
    nb = -(-n // cfg.block_size)
    if q_positions is None:
        q_positions = jnp.arange(nq) + (n - nq)
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    sel = moba_selection(q, k, cfg, q_positions,
                         head_top_k=head_top_k)              # (B,H,Nq,k)
    sel_mask = routing.selection_mask(sel, nb)               # (B,H,Nq,nb)
    key_block = jnp.arange(n) // cfg.block_size              # (N,)
    tok_sel = jnp.take_along_axis(
        sel_mask, key_block[None, None, None, :].repeat(nq, 2), axis=-1
    )                                                        # (B,H,Nq,N)
    mask = tok_sel
    if cfg.causal:
        causal = q_positions[:, None] >= jnp.arange(n)[None, :]
        mask = mask & causal[None, None]
    if kv_len is not None:
        mask = mask & (jnp.arange(n)[None, None, None, :] < kv_len)

    qg = _group_queries(q, hkv).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k.astype(jnp.float32)) * scale
    s = s.reshape(b, h, nq, n)
    s = jnp.where(mask, s, NEG_INF)
    # guard fully-masked rows (cannot happen causally: own block present)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    pg = p.reshape(b, hkv, -1, nq, n)
    o = jnp.einsum("bhgqs,bhsd->bhgqd", pg, v.astype(jnp.float32))
    return o.reshape(b, h, nq, d).astype(q.dtype)


def moba_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   cfg: MoBAConfig,
                   key_conv_weights: Optional[jax.Array] = None,
                   impl: str = "reference",
                   q_positions: Optional[jax.Array] = None,
                   scale: Optional[float] = None,
                   interpret: bool = True) -> jax.Array:
    """Public MoBA attention entry point.

    ``impl`` names a registered attention backend (``core.backends``):
    'reference' (O(N^2) oracle), 'flash'/'kernel' (Pallas FlashMoBA),
    'xla'/'sparse' (pure-XLA gather-and-densify), 'sp' (context
    parallel), plus the ``_unrolled`` dry-run variants.  ``interpret``
    only affects the Pallas backend.
    """
    from repro.core import backends as B

    if key_conv_weights is not None:
        k = apply_key_conv(key_conv_weights, k)
    be = B.resolve(impl, kind="moba", phase="prefill", cache="dense",
                   key_conv=key_conv_weights is not None)
    acfg = _as_attention_config(cfg, scale)
    return be.moba_prefill(acfg, q, k, v, q_positions=q_positions,
                           interpret=interpret)


def _as_attention_config(cfg: MoBAConfig, scale: Optional[float]):
    """Wrap a bare MoBAConfig for the backend interface (which takes the
    per-layer AttentionConfig so one signature covers dense/swa/moba)."""
    from repro.configs.base import AttentionConfig
    return AttentionConfig(kind="moba", moba=cfg, scale=scale)


def _topk_pages(masked: jax.Array, top_k: int):
    """Shared tail of paged routing: top-k over the last (page) axis,
    padded with invalid slots when the axis is shorter than ``top_k``.
    Both the decode and the chunked-prefill routes go through this so
    their selection semantics cannot drift apart.

    Returns (idx, sel_valid): selected indices (invalid slots 0) and
    their validity mask (NEG_INF-scored slots are invalid).
    """
    n = masked.shape[-1]
    kk = min(top_k, n)
    top_s, top_idx = jax.lax.top_k(masked, kk)
    if kk < top_k:
        padw = top_k - kk
        top_s = jnp.concatenate(
            [top_s, jnp.full(top_s.shape[:-1] + (padw,), NEG_INF)], -1)
        top_idx = jnp.concatenate(
            [top_idx, jnp.zeros(top_idx.shape[:-1] + (padw,),
                                top_idx.dtype)], -1)
    sel_valid = top_s > NEG_INF / 2
    return jnp.where(sel_valid, top_idx, 0), sel_valid


def moba_paged_route(q: jax.Array, centroids: jax.Array,
                     block_table: jax.Array, kv_len: jax.Array,
                     cfg: MoBAConfig,
                     page_size: Optional[int] = None,
                     head_top_k: Optional[jax.Array] = None):
    """Decode-time page routing on the per-page centroid cache.

    Shared by the XLA gather path and the Pallas decode kernel wrapper so
    both attend to exactly the same pages.  Matches the dense-cache
    decode selection semantics: causal over pages, own (last) page
    forced, per-sequence lengths, top-k padded with invalid slots when
    the table is shorter than ``top_k``.

    q:           (B, H, 1, d)
    centroids:   (P, Hkv, d) fp32 per-page centroid pool
    block_table: (B, npg) int32 physical page ids, -1 = unassigned
    kv_len:      (B,) int32 post-append valid lengths

    Returns (idx, sel_valid): logical page ids (B, Hkv, G, 1, top_k)
    int32 (invalid slots 0) and their validity mask.  ``head_top_k``
    ((Hkv, G) int32 in [1, top_k]) truncates each head's score-sorted
    selection to its calibrated budget (DESIGN.md §8).
    """
    b, h, _, d = q.shape
    hkv = centroids.shape[1]
    npg = block_table.shape[1]
    ps = page_size or cfg.block_size  # one page == one routable block
    tbl = jnp.maximum(block_table, 0)
    cents = centroids[tbl].transpose(0, 2, 1, 3)             # (B,Hkv,npg,d)
    qg = _group_queries(q, hkv).astype(jnp.float32)          # (B,Hkv,G,1,d)
    scores = jnp.einsum("bhgqd,bhnd->bhgqn", qg,
                        cents.astype(jnp.float32))
    blk_start = jnp.arange(npg) * ps
    valid = (blk_start[None, :] < kv_len[:, None]) & (block_table >= 0)
    own = jnp.maximum(kv_len - 1, 0) // ps                   # (B,)
    is_own = jnp.arange(npg)[None, :] == own[:, None]        # (B,npg)
    masked = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    masked = jnp.where(is_own[:, None, None, None], routing.POS_INF, masked)
    idx, sel_valid = _topk_pages(masked, cfg.top_k)
    return _truncate_head_topk(idx, sel_valid, head_top_k)


def moba_paged_decode_attention(q: jax.Array, pages_k: jax.Array,
                                pages_v: jax.Array, centroids: jax.Array,
                                block_table: jax.Array, kv_len: jax.Array,
                                cfg: MoBAConfig,
                                scale: Optional[float] = None,
                                scales_k: Optional[jax.Array] = None,
                                scales_v: Optional[jax.Array] = None,
                                head_top_k: Optional[jax.Array] = None
                                ) -> jax.Array:
    """Single-step decode against a paged cache: route on the per-page
    centroid cache, then gather only the ``top_k`` selected pages through
    the block table — O(N/B·d) routing reads + O(k·B·d) attention reads
    per kv head, never touching the rest of the pool.

    q:           (B, H, 1, d)
    pages_k/v:   (P, page_size, Hkv, d) shared pool (one layer slot)
    centroids:   (P, Hkv, d) fp32 per-page centroid cache
    block_table: (B, npg) int32 physical page ids, -1 = unassigned
    kv_len:      (B,) int32 valid lengths *including* the token appended
                 this step (call after the cache append)
    scales_k/v:  (P, Hkv) fp32 per-page dequant scales of a quantized
                 pool (None = unquantized).  Routing above never sees
                 them — centroids are fp32 regardless of pool dtype.
    """
    b, h, _, d = q.shape
    _, ps, hkv, _ = pages_k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    idx, sel_valid = moba_paged_route(q, centroids, block_table, kv_len,
                                      cfg, page_size=ps,
                                      head_top_k=head_top_k)
    qg = _group_queries(q, hkv).astype(jnp.float32)          # (B,Hkv,G,1,d)
    tbl = jnp.maximum(block_table, 0)
    phys = tbl[jnp.arange(b)[:, None, None, None, None], idx]

    # gather only the selected pages, per kv head: (B,Hkv,G,1,k,ps,d)
    pk_t = pages_k.transpose(2, 0, 1, 3)                     # (Hkv,P,ps,d)
    pv_t = pages_v.transpose(2, 0, 1, 3)

    def per_head(pool_h, idx_h):                             # (P,ps,d)
        return pool_h[idx_h]                                 # (B,G,1,k,ps,d)

    kg = jax.vmap(per_head, in_axes=(0, 1), out_axes=1)(
        pk_t, phys)
    vg = jax.vmap(per_head, in_axes=(0, 1), out_axes=1)(
        pv_t, phys)
    kg = kg.astype(jnp.float32)
    vg = vg.astype(jnp.float32)
    if scales_k is not None:
        # mirror the page gather on the (P, Hkv) scale leaves: one
        # scalar per selected (page, kv head), broadcast over (ps, d)
        hsel = jnp.arange(hkv)[None, :, None, None, None]
        kg = kg * scales_k[phys, hsel][..., None, None]
        vg = vg * scales_v[phys, hsel][..., None, None]
    s = jnp.einsum("bhgqd,bhgqkld->bhgqkl", qg, kg) * scale
    pos = idx[..., :, None] * ps + jnp.arange(ps)            # logical pos
    tok_valid = ((pos < kv_len[:, None, None, None, None, None])
                 & sel_valid[..., None])
    s = jnp.where(tok_valid, s, NEG_INF)
    sf = s.reshape(*s.shape[:-2], -1)
    p = jax.nn.softmax(sf, axis=-1).reshape(s.shape)
    o = jnp.einsum("bhgqkl,bhgqkld->bhgqd", p, vg)
    return o.reshape(b, h, 1, d).astype(q.dtype)


def moba_paged_prefill_route(q: jax.Array, centroids: jax.Array,
                             block_table: jax.Array, kv_len: jax.Array,
                             q_len: jax.Array, cfg: MoBAConfig,
                             page_size: Optional[int] = None,
                             head_top_k: Optional[jax.Array] = None):
    """Chunked-prefill page routing on the per-page centroid cache.

    Multi-token sibling of :func:`moba_paged_route`: query j of row i sits
    at absolute position ``kv_len[i] + j`` and scores every logical page
    of its sequence, with future pages masked, the own page forced, and
    unassigned table entries invalid.  Call *after* the chunk's keys (and
    centroid recomputes) are appended, so complete pages carry exactly
    the centroids one-shot prefill would compute — any non-own page a
    query can select is complete by then, which is what makes chunked and
    one-shot prefill routing-equivalent (DESIGN.md §6; pinned by test).

    q: (B, H, L, d) right-padded chunk queries; centroids: (P, Hkv, d);
    block_table: (B, npg); kv_len: (B,) pre-chunk lengths; q_len: (B,)
    valid chunk tokens per row.

    Returns (idx, sel_valid): logical page ids (B, Hkv, G, L, top_k)
    int32 (invalid slots 0) and their validity mask.
    """
    b, h, nq, d = q.shape
    hkv = centroids.shape[1]
    npg = block_table.shape[1]
    ps = page_size or cfg.block_size  # one page == one routable block
    tbl = jnp.maximum(block_table, 0)
    cents = centroids[tbl].transpose(0, 2, 1, 3)             # (B,Hkv,npg,d)
    qg = _group_queries(q, hkv).astype(jnp.float32)          # (B,Hkv,G,L,d)
    scores = jnp.einsum("bhgqd,bhnd->bhgqn", qg,
                        cents.astype(jnp.float32))
    pos = kv_len[:, None] + jnp.arange(nq)                   # (B,L) abs pos
    own = pos // ps                                          # (B,L)
    blk = jnp.arange(npg)
    future = blk[None, None, :] > own[:, :, None]            # (B,L,npg)
    is_own = blk[None, None, :] == own[:, :, None]
    assigned = (block_table >= 0)[:, None, :]                # (B,1,npg)
    # broadcast (B,L,npg) masks into (B,Hkv,G,L,npg)
    masked = jnp.where((future | ~assigned)[:, None, None], NEG_INF, scores)
    masked = jnp.where(is_own[:, None, None], routing.POS_INF, masked)
    idx, sel_valid = _topk_pages(masked, cfg.top_k)
    idx, sel_valid = _truncate_head_topk(idx, sel_valid, head_top_k)
    # padded query rows (beyond q_len) select nothing
    row_valid = (jnp.arange(nq) < q_len[:, None])            # (B,L)
    sel_valid = sel_valid & row_valid[:, None, None, :, None]
    return jnp.where(sel_valid, idx, 0), sel_valid


def moba_paged_prefill_attention(q: jax.Array, pages_k: jax.Array,
                                 pages_v: jax.Array, centroids: jax.Array,
                                 block_table: jax.Array, kv_len: jax.Array,
                                 q_len: jax.Array, cfg: MoBAConfig,
                                 scale: Optional[float] = None,
                                 scales_k: Optional[jax.Array] = None,
                                 scales_v: Optional[jax.Array] = None,
                                 head_top_k: Optional[jax.Array] = None
                                 ) -> jax.Array:
    """Chunked-prefill MoBA attention against a paged cache.

    The chunk's queries route on the per-page centroid cache
    (:func:`moba_paged_prefill_route`), then attend over the densified
    sequence view of the pool under the selection × causal mask — earlier
    chunks' keys are visible through the block table, which is what the
    fresh-prefill path cannot do.  Padded query rows (beyond ``q_len``)
    select nothing and output zeros.

    q: (B, H, L, d); pages_k/v: (P, ps, Hkv, d); centroids: (P, Hkv, d);
    block_table: (B, npg); kv_len: (B,) pre-chunk lengths (the chunk and
    its centroid updates must already be appended); q_len: (B,);
    scales_k/v: (P, Hkv) fp32 per-page dequant scales of a quantized
    pool (None = unquantized) — applied on the densified view, never to
    the routing centroids.
    """
    b, h, nq, d = q.shape
    _, ps, hkv, _ = pages_k.shape
    npg = block_table.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    idx, sel_valid = moba_paged_prefill_route(q, centroids, block_table,
                                              kv_len, q_len, cfg,
                                              page_size=ps,
                                              head_top_k=head_top_k)
    sel_mask = routing.selection_mask(
        jnp.where(sel_valid, idx, npg), npg)                 # (B,Hkv,G,L,npg)
    pos = kv_len[:, None] + jnp.arange(nq)                   # (B,L) abs pos
    key_pos = (jnp.arange(npg * ps))                         # logical order
    causal = pos[:, :, None] >= key_pos[None, None, :]       # (B,L,n)
    tok_sel = jnp.repeat(sel_mask, ps, axis=-1)              # (B,Hkv,G,L,n)
    mask = tok_sel & causal[:, None, None]

    tbl = jnp.maximum(block_table, 0)

    def densify(pool, scales):
        g = pool[tbl].astype(jnp.float32)                    # (B,npg,ps,h,d)
        if scales is not None:
            g = g * scales[tbl][:, :, None, :, None]
        return g.transpose(0, 3, 1, 2, 4).reshape(b, hkv, npg * ps, d)

    kf = densify(pages_k, scales_k)
    vf = densify(pages_v, scales_v)
    qg = _group_queries(q, hkv).astype(jnp.float32)          # (B,Hkv,G,L,d)
    s = jnp.einsum("bhgqd,bhsd->bhgqs", qg, kf) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhgqs,bhsd->bhgqd", p, vf)
    return o.reshape(b, h, nq, d).astype(q.dtype)


def moba_decode_attention(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, kv_len: jax.Array,
                          cfg: MoBAConfig,
                          scale: Optional[float] = None,
                          centroids: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Single-step decode: q (B, H, 1, d) against a (B, Hkv, Nmax, d) cache
    of which the first ``kv_len`` positions are valid.

    Reads only centroids + the k selected blocks: O(Nmax/B · d + k·B·d) per
    query head — the sub-quadratic decode path MoBA exists for.
    """
    b, h, _, d = q.shape
    _, hkv, nmax, _ = k_cache.shape
    bs = cfg.block_size
    nb = -(-nmax // bs)
    if nb * bs != nmax:  # ragged cache tail: pad (padded tokens are
        # masked out by the kv_len check below)
        k_cache = routing.pad_to_blocks(k_cache, bs, axis=-2)
        v_cache = routing.pad_to_blocks(v_cache, bs, axis=-2)
        nmax = nb * bs
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    # incremental centroid cache (N/B·d reads) when available; otherwise
    # recompute from the full cache (N·d reads — the baseline cost)
    cents = (centroids if centroids is not None
             else routing.block_centroids(k_cache, bs, kv_len=kv_len))
    qg = _group_queries(q, hkv).astype(jnp.float32)          # (B,Hkv,G,1,d)
    scores = jnp.einsum("bhgqd,bhnd->bhgqn", qg,
                        cents.astype(jnp.float32))
    # causal over blocks: block j valid iff it contains any position < kv_len
    blk_start = jnp.arange(nb) * bs
    valid = blk_start < kv_len                               # (nb,) or (B,1..)
    valid = jnp.broadcast_to(valid, scores.shape[:-1] + (nb,))
    own = jnp.maximum(kv_len - 1, 0) // bs
    is_own = jnp.arange(nb) == own
    is_own = jnp.broadcast_to(is_own, scores.shape[:-1] + (nb,))
    masked = jnp.where(valid, scores, NEG_INF)
    masked = jnp.where(is_own, routing.POS_INF, masked)
    top_s, top_idx = jax.lax.top_k(masked, min(cfg.top_k, nb))  # (...,k)
    if top_idx.shape[-1] < cfg.top_k:
        padw = cfg.top_k - top_idx.shape[-1]
        top_s = jnp.concatenate(
            [top_s, jnp.full(top_s.shape[:-1] + (padw,), NEG_INF)], -1)
        top_idx = jnp.concatenate(
            [top_idx, jnp.zeros(top_idx.shape[:-1] + (padw,),
                                top_idx.dtype)], -1)
    sel_valid = top_s > NEG_INF / 2

    # gather the k selected blocks: (B,Hkv,G,1,k,bs,d)
    kb = k_cache.reshape(b, hkv, nb, bs, d)
    vb = v_cache.reshape(b, hkv, nb, bs, d)
    idx = jnp.where(sel_valid, top_idx, 0)

    def gather_blocks(blocks, sel):     # blocks (nb,bs,d), sel (G,1,k)
        return blocks[sel]              # (G,1,k,bs,d)

    kg = jax.vmap(jax.vmap(gather_blocks))(kb, idx)
    vg = jax.vmap(jax.vmap(gather_blocks))(vb, idx)
    s = jnp.einsum("bhgqd,bhgqkld->bhgqkl", qg, kg.astype(jnp.float32))
    s = s * scale
    pos = idx[..., :, None] * bs + jnp.arange(bs)            # (...,k,bs)
    tok_valid = (pos < kv_len) & sel_valid[..., None]
    s = jnp.where(tok_valid, s, NEG_INF)
    sf = s.reshape(*s.shape[:-2], -1)
    p = jax.nn.softmax(sf, axis=-1).reshape(s.shape)
    o = jnp.einsum("bhgqkl,bhgqkld->bhgqd", p, vg.astype(jnp.float32))
    return o.reshape(b, h, 1, d).astype(q.dtype)
