"""Roofline term extraction from compiled dry-run artifacts.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  ``cost_analysis()`` on an SPMD executable reports **per-device**
FLOPs/bytes, so the three terms are computed on a per-chip basis:

  compute   = flops_per_device / PEAK_FLOPS
  memory    = bytes_per_device / HBM_BW
  collective= collective_bytes_per_device / ICI_BW

collective bytes are parsed from the *compiled* (post-SPMD) HLO: per-device
operand sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, with ring-algorithm multipliers (all-reduce moves ~2×
its payload per device; others ~1×).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{",
                       re.M)
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+), "
                       r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


_HDR_LINE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_computations(text: str):
    """HLO text -> {comp_name: body_text}, plus the entry comp name.

    Line-based: computation headers are single lines ending in '{' (nested
    parens in tuple-typed params break a regex-only approach)."""
    comps, entry, cur, buf = {}, None, None, []
    for line in text.splitlines():
        if cur is None:
            m = _HDR_LINE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                buf = []
                if m.group(1):
                    entry = cur
        elif line.startswith("}"):
            comps[cur] = "\n".join(buf)
            cur = None
        else:
            buf.append(line)
    return comps, entry


def _direct_collectives(body: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for line in body.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        ty = line.split("=", 1)[1].split(m.group(1))[0]
        nbytes = _shape_bytes(ty)
        out[kind] = out.get(kind, 0.0) + nbytes * _MULT[kind]
    return out


def collective_bytes(compiled_text: str) -> Dict[str, float]:
    """Per-device collective payload bytes from compiled (post-SPMD) HLO.

    While-loop bodies (lax.scan over layer groups, remat recompute loops)
    are multiplied by their trip count, recovered from the `constant(N)`
    bound in the loop's condition computation — XLA's cost/HLO tools count
    loop bodies only once, which under-reports per-layer collectives by
    the layer count otherwise.
    """
    comps, entry = _split_computations(compiled_text)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return {}

    def trip_count(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(body)]
        return max(consts) if consts else 1

    def visit(name: str, seen) -> Dict[str, float]:
        if name in seen:            # guard malformed recursion
            return {}
        seen = seen | {name}
        body = comps.get(name, "")
        total = _direct_collectives(body)
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.groups()
            t = trip_count(cond)
            sub = visit(wbody, seen)
            for k, v in sub.items():
                total[k] = total.get(k, 0.0) + v * t
        return total

    return visit(entry, frozenset())


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]
    model_flops: float              # 6·N·D (train) / 2·N·D (fwd), global
    peak_memory_bytes: float        # per-device from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / bound time: MODEL_FLOPS/(chips·peak) over
        the dominant term — the MFU-analogue we can compute pre-silicon."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / bound if bound else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int, compiled,
            model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    peak = (ma.temp_size_in_bytes + ma.argument_size_in_bytes
            + ma.output_size_in_bytes) if ma else 0
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll, model_flops=model_flops,
        peak_memory_bytes=float(peak))


def format_table(rows) -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'mesh':<10}{'t_comp(s)':>11}"
           f"{'t_mem(s)':>11}{'t_coll(s)':>11}{'bound':>11}"
           f"{'useful':>8}{'roofl%':>8}{'GB/dev':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<22}{r.shape:<13}{r.mesh:<10}"
            f"{r.t_compute:>11.3e}{r.t_memory:>11.3e}"
            f"{r.t_collective:>11.3e}{r.bottleneck:>11}"
            f"{r.useful_flops_ratio:>8.2f}"
            f"{100 * r.roofline_fraction:>7.1f}%"
            f"{r.peak_memory_bytes / 1e9:>8.2f}")
    return "\n".join(lines)
