import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Official measurements for the three hillclimbed cells (§Perf)."""

import json  # noqa: E402

import jax   # noqa: E402

import repro.launch.dryrun as DR                       # noqa: E402
from repro.configs.base import ShardingConfig          # noqa: E402
from repro.launch import roofline as RL                # noqa: E402
from repro.launch import steps as S                    # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402


def measure(arch, shape, tag, scfg=None, microbatch=None, **kw):
    mesh = make_production_mesh()
    if microbatch is not None:
        DR.MICROBATCH[arch] = microbatch
    lowered, cfg = DR.build_lowered(arch, shape, mesh, backend="sp",
                                    unroll=False, scfg=scfg, **kw)
    compiled = lowered.compile()
    lowered2, _ = DR.build_lowered(arch, shape, mesh,
                                   backend="sp_unrolled", unroll=True,
                                   scfg=scfg, **kw)
    ca2 = lowered2.cost_analysis()
    ca2 = ca2[0] if isinstance(ca2, list) else ca2
    rl = RL.analyze(arch, shape, "16x16", 256, compiled,
                    S.model_flops(cfg, shape))
    rl = RL.Roofline(**{**rl.__dict__,
                        "flops_per_device": float(ca2.get("flops", 0)) / 256,
                        "bytes_per_device":
                        float(ca2.get("bytes accessed", 0)) / 256})
    os.makedirs("experiments/hillclimb", exist_ok=True)
    with open(f"experiments/hillclimb/{tag}.json", "w") as f:
        json.dump(rl.to_dict(), f, indent=1)
    print(f"{tag}: t_comp={rl.t_compute:.3e} t_mem={rl.t_memory:.3e} "
          f"t_coll={rl.t_collective:.3e} bound={rl.bottleneck} "
          f"roofline={100*rl.roofline_fraction:.1f}% "
          f"mem={rl.peak_memory_bytes/1e9:.1f}GB")
    return rl


if __name__ == "__main__":
    # C: paper-representative — FSDP+SP (no feature TP)
    measure("qwen3-14b", "prefill_32k", "qwen3-14b__prefill_32k__opt",
            scfg=ShardingConfig(tensor_parallel=False,
                                sequence_parallel=True))
    # A: worst-roofline — 2D expert-sharded dispatch (code-level fix)
    measure("qwen2-moe-a2.7b", "train_4k", "qwen2-moe__train_4k__opt")
    # B: most collective-bound — microbatch trade-off point
    measure("llama-3.2-vision-90b", "train_4k", "llama-90b__train_4k__opt",
            microbatch=8)
