"""Serving driver: paged-KV continuous-batching engine (default) with a
legacy fixed-batch fallback for archs the engine does not cover.

  # Poisson request stream through the engine, throughput + latency:
  PYTHONPATH=src python -m repro.launch.serve --smoke

  # fixed synchronous batch (old behaviour / ssm + encdec + vlm archs):
  PYTHONPATH=src python -m repro.launch.serve --smoke --mode fixed \
      --arch mamba2-780m --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as S
from repro.models import transformer as T
from repro.serving.engine import Engine, EngineConfig, engine_supported
from repro.serving.scheduler import ServingError


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _make_engine(cfg, params, ecfg: EngineConfig, shards: int):
    """Single-host Engine (shards == 0) or the sharded fleet.  Sharded
    sizing in ``ecfg`` is per shard, matching ShardedEngine semantics."""
    if shards:
        from repro.serving.sharded import ShardedEngine
        return ShardedEngine(cfg, params, ecfg, n_shards=shards)
    return Engine(cfg, params, ecfg)


def serve(arch: str, batch: int = 4, prompt_len: int = 64, gen: int = 32,
          smoke: bool = True, attn_backend: str = "reference",
          seed: int = 0, use_engine: str = "auto",
          prefill_chunk: int = 0, shards: int = 0,
          prefix_cache: bool = False, swap_bytes: int = None,
          kv_dtype: str = "fp32", route_policy: str = "static"):
    """Decode ``gen`` greedy tokens for ``batch`` random prompts.

    Routes through the paged continuous-batching engine when the arch
    supports it (``use_engine='auto'``); otherwise — recurrent, enc-dec
    and cross-attention archs — through the legacy fixed-batch loop.
    ``attn_backend`` names a registered attention backend
    (``core.backends``).  ``shards > 0`` serves through the sharded
    engine (``serving/sharded.py``) with that many page-pool shards.
    Returns int32 tokens of shape (batch, gen) either way.
    """
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    if use_engine == "never" or (use_engine == "auto"
                                 and not engine_supported(cfg)):
        return serve_fixed(arch, batch=batch, prompt_len=prompt_len,
                           gen=gen, smoke=smoke,
                           attn_backend=attn_backend, seed=seed)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)
    kw = {} if swap_bytes is None else {"swap_bytes": swap_bytes}
    eng = _make_engine(cfg, params, EngineConfig(
        max_seqs=batch, max_seq_len=_round_up(prompt_len + gen, 16),
        max_prefill_batch=min(batch, 4), attn_backend=attn_backend,
        prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
        kv_dtype=kv_dtype, route_policy=route_policy, **kw),
        shards)
    reqs = [eng.submit(prompts[i], max_new_tokens=gen)
            for i in range(batch)]
    eng.run()
    st = eng.stats
    print(f"engine: {st['prefill_tokens']} prefill tokens in "
          f"{st['prefill_s']:.2f}s; {st['decode_tokens']} decode tokens "
          f"in {st['decode_s']:.2f}s over {st['decode_steps']} steps "
          f"({st['decode_tokens'] / max(st['decode_s'], 1e-9):.1f} tok/s)")
    return jnp.asarray(np.stack([np.asarray(r.out[:gen], np.int32)
                                 for r in reqs]))


def serve_stream(arch: str, n_requests: int = 16, rate: float = 8.0,
                 prompt_range=(16, 96), gen_range=(8, 48),
                 max_seqs: int = 8, num_pages: int = 0,
                 smoke: bool = True, attn_backend: str = "reference",
                 seed: int = 0, realtime: bool = True,
                 prefill_chunk: int = 0, shards: int = 0,
                 prefix_cache: bool = False,
                 swap_bytes: int = None,
                 kv_dtype: str = "fp32",
                 route_policy: str = "static") -> dict:
    """Continuous-batching scenario: Poisson arrivals (``rate`` req/s),
    mixed prompt/generation lengths.  Reports tokens/s and p50/p99
    time-to-first-token + end-to-end latency (per shard too when
    ``shards > 0``).

    ``realtime=False`` collapses the arrival process (every request is
    queued at t=0) so percentiles stay meaningful as queueing-free
    engine latencies — honouring fictional arrivals against a free-
    running clock would make them negative."""
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    max_len = _round_up(prompt_range[1] + gen_range[1], 16)
    kw = {} if swap_bytes is None else {"swap_bytes": swap_bytes}
    eng = _make_engine(cfg, params, EngineConfig(
        max_seqs=max_seqs, max_seq_len=max_len, num_pages=num_pages,
        attn_backend=attn_backend, prefill_chunk=prefill_chunk,
        prefix_cache=prefix_cache, kv_dtype=kv_dtype,
        route_policy=route_policy, **kw), shards)
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(*prompt_range))
        glen = int(rng.integers(*gen_range))
        eng.submit(rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                   max_new_tokens=glen,
                   arrival=t if realtime else 0.0)
    t0 = time.perf_counter()
    done = eng.run(realtime=realtime)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    ttft = np.array([r.t_first - r.arrival for r in done])
    lat = np.array([r.t_done - r.arrival for r in done])
    metrics = {
        "requests": len(done), "wall_s": wall,
        "generated_tokens": total_tokens,
        "tokens_per_s": total_tokens / max(wall, 1e-9),
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "preemptions": eng.stats["preemptions"],
        "decode_steps": eng.stats["decode_steps"],
    }
    if prefix_cache:
        st = eng.stats
        metrics["prefix_hit_rate"] = (
            st["prefix_hit_tokens"] / max(st["prefix_prompt_tokens"], 1))
        metrics["prefix_hit_tokens"] = st["prefix_hit_tokens"]
        metrics["cow_copies"] = st["cow_copies"]
        metrics["tree_evictions"] = st["tree_evictions"]
        metrics["swap_restores"] = st["swap_restores"]
        metrics["pages_in_use_peak"] = st["pages_in_use_peak"]
    if shards:
        dec_s = max(eng.stats["decode_s"], 1e-9)
        metrics["per_shard_tokens_per_s"] = [
            st["decode_tokens"] / dec_s for st in eng.shard_stats]
        metrics["per_shard_requests"] = [st["requests"]
                                         for st in eng.shard_stats]
    print(f"stream: {metrics['requests']} requests, "
          f"{metrics['generated_tokens']} tokens in {wall:.2f}s "
          f"({metrics['tokens_per_s']:.1f} tok/s); "
          f"ttft p50/p99 {metrics['ttft_p50_ms']:.0f}/"
          f"{metrics['ttft_p99_ms']:.0f} ms; "
          f"latency p50/p99 {metrics['latency_p50_ms']:.0f}/"
          f"{metrics['latency_p99_ms']:.0f} ms; "
          f"{metrics['preemptions']} preemptions")
    if prefix_cache:
        print(f"  prefix cache: hit rate {metrics['prefix_hit_rate']:.2f} "
              f"({metrics['prefix_hit_tokens']} tokens), "
              f"{metrics['cow_copies']} COW copies, "
              f"{metrics['tree_evictions']} evictions, "
              f"{metrics['swap_restores']} swap restores, "
              f"peak {metrics['pages_in_use_peak']} pages")
    if shards:
        for s, tps in enumerate(metrics["per_shard_tokens_per_s"]):
            print(f"  shard {s}: {metrics['per_shard_requests'][s]} "
                  f"requests, {tps:.1f} tok/s")
    return metrics


def serve_openloop(arch: str, n_requests: int = 16, every: int = 4,
                   prompt_range=(16, 96), gen_range=(8, 48),
                   max_seqs: int = 8, num_pages: int = 0,
                   smoke: bool = True, attn_backend: str = "reference",
                   seed: int = 0, prefill_chunk: int = 0,
                   shards: int = 0, prefix_cache: bool = False,
                   swap_bytes: int = None, kv_dtype: str = "fp32",
                   route_policy: str = "static",
                   dispatch_ahead: int = 1) -> dict:
    """Open-loop scenario over the STAGED API: one request arrives every
    ``every`` decode steps whether or not the engine keeps up, driven by
    ``serving.frontend.run_open_loop`` with dispatch-ahead decode.
    Reports sustained tokens/s plus TTFT/TPOT percentiles."""
    from repro.serving import frontend as FE
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    max_len = _round_up(prompt_range[1] + gen_range[1], 16)
    kw = {} if swap_bytes is None else {"swap_bytes": swap_bytes}
    eng = _make_engine(cfg, params, EngineConfig(
        max_seqs=max_seqs, max_seq_len=max_len, num_pages=num_pages,
        attn_backend=attn_backend, prefill_chunk=prefill_chunk,
        prefix_cache=prefix_cache, kv_dtype=kv_dtype,
        route_policy=route_policy, dispatch_ahead=dispatch_ahead, **kw),
        shards)
    trace = [FE.TraceItem(
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(*prompt_range)),
                            dtype=np.int32),
        max_new_tokens=int(rng.integers(*gen_range)),
        arrival_step=i * every) for i in range(n_requests)]
    metrics = FE.time_open_loop(eng, trace)
    metrics.pop("_requests")
    print(f"open-loop: {metrics['requests']} requests, "
          f"{metrics['generated_tokens']} tokens in "
          f"{metrics['wall_s']:.2f}s "
          f"({metrics['sustained_tokens_per_s']:.1f} tok/s sustained); "
          f"ttft p50/p99 {metrics['ttft_p50_ms']:.0f}/"
          f"{metrics['ttft_p99_ms']:.0f} ms; "
          f"tpot p50/p99 {metrics['tpot_p50_ms']:.1f}/"
          f"{metrics['tpot_p99_ms']:.1f} ms; "
          f"pipeline depth peak {metrics['dispatch_depth_peak']} "
          f"(dispatch_ahead={dispatch_ahead}); "
          f"{metrics['preemptions']} preemptions")
    return metrics


def serve_http(arch: str, port: int, host: str = "127.0.0.1",
               max_seqs: int = 8, num_pages: int = 0, smoke: bool = True,
               attn_backend: str = "reference", seed: int = 0,
               prefill_chunk: int = 0, shards: int = 0,
               prefix_cache: bool = False, swap_bytes: int = None,
               kv_dtype: str = "fp32", route_policy: str = "static",
               dispatch_ahead: int = 1,
               max_seq_len: int = 512) -> None:
    """Minimal stdlib-asyncio HTTP front end over :class:`AsyncFrontend`.

      POST /generate  {"prompt": [ids...], "max_new_tokens": N}
        → JSON lines, one {"token": t} per generated token, then a
          final {"done": true, "tokens": [...], "ttft_ms": ...} record
          (Connection: close framing — curl streams it as it decodes).
      GET /stats → engine stats snapshot.

    Serves until interrupted.  One engine, many concurrent connections:
    the frontend's pump task interleaves their requests through the
    staged API with dispatch-ahead decode."""
    import asyncio
    import json

    from repro.serving import frontend as FE
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    kw = {} if swap_bytes is None else {"swap_bytes": swap_bytes}
    eng = _make_engine(cfg, params, EngineConfig(
        max_seqs=max_seqs, max_seq_len=_round_up(max_seq_len, 16),
        num_pages=num_pages, attn_backend=attn_backend,
        prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
        kv_dtype=kv_dtype, route_policy=route_policy,
        dispatch_ahead=dispatch_ahead, **kw), shards)
    fe = FE.AsyncFrontend(eng)

    def _resp(writer, status: str, body: bytes,
              ctype: str = "application/json") -> None:
        writer.write(
            f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)

    async def handle(reader, writer):
        try:
            head = await reader.readuntil(b"\r\n\r\n")
            line, _, rest = head.partition(b"\r\n")
            method, path, _ = line.decode().split(" ", 2)
            clen = 0
            for h in rest.decode().split("\r\n"):
                if h.lower().startswith("content-length:"):
                    clen = int(h.split(":", 1)[1])
            body = await reader.readexactly(clen) if clen else b""
            if method == "GET" and path == "/stats":
                _resp(writer, "200 OK",
                      json.dumps(eng.stats).encode() + b"\n")
            elif method == "POST" and path == "/generate":
                spec = json.loads(body)
                req = fe.submit(
                    np.asarray(spec["prompt"], np.int32),
                    max_new_tokens=int(spec.get("max_new_tokens", 32)),
                    eos_id=spec.get("eos_id"))
                writer.write(b"HTTP/1.1 200 OK\r\n"
                             b"Content-Type: application/json\r\n"
                             b"Connection: close\r\n\r\n")
                async for tok in fe.stream(req):
                    writer.write(json.dumps({"token": tok}).encode()
                                 + b"\n")
                    await writer.drain()
                writer.write(json.dumps(
                    {"done": True, "tokens": list(req.out),
                     "ttft_ms": (req.t_first - req.arrival) * 1e3,
                     "preempted": req.n_preempt > 0}).encode() + b"\n")
            else:
                _resp(writer, "404 Not Found", b'{"error": "not found"}\n')
            await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            writer.close()

    async def main_async():
        await fe.start()
        server = await asyncio.start_server(handle, host, port)
        addr = server.sockets[0].getsockname()
        print(f"serving {arch} on http://{addr[0]}:{addr[1]} "
              f"(POST /generate, GET /stats)")
        async with server:
            await server.serve_forever()

    try:
        asyncio.run(main_async())
    except KeyboardInterrupt:
        pass


def serve_fixed(arch: str, batch: int = 4, prompt_len: int = 64,
                gen: int = 32, smoke: bool = True,
                attn_backend: str = "reference", seed: int = 0):
    """Legacy synchronous loop: one dense-cache prefill + lockstep greedy
    decode.  Baseline for benchmarks and the fallback for recurrent /
    enc-dec / cross-attention archs the paged engine does not cover."""
    from repro.core import backends as B
    attn_backend = B.resolve_backend_spec(attn_backend,
                                          default="reference")
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["cross_kv"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.num_image_tokens, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "encdec":
        extras["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.num_audio_frames, cfg.d_model)),
            cfg.dtype)

    max_len = prompt_len + gen
    caches = T.init_caches(cfg, batch, max_len,
                           dtype=jnp.dtype(cfg.dtype))
    prefill_fn = jax.jit(S.make_prefill_step(cfg, backend=attn_backend),
                         donate_argnums=(2,))
    decode_fn = jax.jit(S.make_decode_step(cfg, backend=attn_backend),
                        donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill_fn(params, prompts, caches, **extras)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, caches = decode_fn(params, tok, caches, **extras)
        out.append(tok)
    t_decode = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"prefill: {batch}×{prompt_len} tokens in {t_prefill:.2f}s; "
          f"decode: {batch}×{gen} tokens in {t_decode:.2f}s "
          f"({batch * gen / max(t_decode, 1e-9):.1f} tok/s)")
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--mode", default="stream",
                    choices=["stream", "openloop", "batch", "fixed"])
    ap.add_argument("--batch", type=int, default=None,
                    help="batch/fixed modes only (default 4)")
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="batch/fixed modes only (default 64)")
    ap.add_argument("--gen", type=int, default=None,
                    help="batch/fixed modes only (default 32)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="stream mode: Poisson arrival rate, req/s")
    ap.add_argument("--every", type=int, default=4,
                    help="openloop mode: one request arrives every N "
                         "decode steps (deterministic open-loop load)")
    ap.add_argument("--dispatch-ahead", type=int, default=1,
                    help="decode steps the host enqueues before blocking "
                         "on the previous step's tokens (0 = fully "
                         "synchronous dispatch)")
    ap.add_argument("--http", type=int, default=0, metavar="PORT",
                    help="serve an asyncio HTTP front end on this port "
                         "instead of running a canned scenario "
                         "(POST /generate streams JSON-lines tokens)")
    ap.add_argument("--max-seqs", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = fully provisioned); "
                         "undersize it to exercise preemption")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: cache prompts in chunks of "
                         "this many tokens across engine steps "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix caching: requests sharing a "
                         "cached token prefix reuse its KV pages "
                         "(copy-on-write) and prefill only the suffix")
    ap.add_argument("--swap-bytes", type=int, default=None,
                    help="host-memory budget for preemption swap "
                         "(bytes; 0 disables swap so preempted requests "
                         "recompute; default 64 MiB)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=["fp32", "int8", "fp8"],
                    help="K/V page-pool storage precision: quantized "
                         "pools store int8/fp8 payload with per-page "
                         "per-kv-head fp32 scales; centroids and routing "
                         "stay fp32.  Backends must declare the dtype in "
                         "Capabilities.kv_dtypes (reference/sp are "
                         "fp32-only)")
    ap.add_argument("--route-policy", default="static",
                    help="MoBA routing policy: 'static' (uniform top_k), "
                         "'snr:pfail=P' (SNR-calibrated per-layer/per-"
                         "head top_k targeting retrieval-failure budget "
                         "P, e.g. snr:pfail=0.01), or 'profile:PATH' "
                         "(load a saved routing-profile artifact) — "
                         "core/adaptive.py, DESIGN.md §8")
    ap.add_argument("--shards", type=int, default=0,
                    help="page-pool shards over the mesh data axis "
                         "(0 = single-host engine); per-shard sizing "
                         "comes from --max-seqs / --num-pages")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn-backend", default=None,
                    help="registered attention backend, optionally with "
                         "a backend option suffix "
                         "(reference | xla | flash | sp | ..., see "
                         "core.backends; default reference).  Pallas "
                         "backends take :interpret / :compiled to force "
                         "the lowering mode and :grouped / :flat to pick "
                         "the paged-decode grid, e.g. "
                         "--attn-backend flash:compiled; default is the "
                         "REPRO_PALLAS_INTERPRET env var, else compiled "
                         "on TPU hosts and interpret elsewhere")
    ap.add_argument("--moba-impl", default=None,
                    help=argparse.SUPPRESS)   # removed: structured error
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    backend = args.attn_backend or "reference"
    try:
        if args.moba_impl is not None:
            raise ServingError(
                f"--moba-impl was removed; use --attn-backend "
                f"{args.moba_impl} (same values — no silent precedence "
                f"between the two flags)")
        if args.http:
            serve_http(args.arch, port=args.http,
                       max_seqs=args.max_seqs, num_pages=args.num_pages,
                       smoke=args.smoke, attn_backend=backend,
                       seed=args.seed, prefill_chunk=args.prefill_chunk,
                       shards=args.shards,
                       prefix_cache=args.prefix_cache,
                       swap_bytes=args.swap_bytes,
                       kv_dtype=args.kv_dtype,
                       route_policy=args.route_policy,
                       dispatch_ahead=args.dispatch_ahead)
        elif args.mode == "openloop":
            serve_openloop(args.arch, n_requests=args.requests,
                           every=args.every, max_seqs=args.max_seqs,
                           num_pages=args.num_pages, smoke=args.smoke,
                           attn_backend=backend, seed=args.seed,
                           prefill_chunk=args.prefill_chunk,
                           shards=args.shards,
                           prefix_cache=args.prefix_cache,
                           swap_bytes=args.swap_bytes,
                           kv_dtype=args.kv_dtype,
                           route_policy=args.route_policy,
                           dispatch_ahead=args.dispatch_ahead)
        elif args.mode == "stream":
            ignored = [n for n, v in (("--batch", args.batch),
                                      ("--prompt-len", args.prompt_len),
                                      ("--gen", args.gen)) if v is not None]
            if ignored:
                print(f"warning: {', '.join(ignored)} only apply to "
                      f"--mode batch/fixed; stream mode draws mixed "
                      f"lengths from its own ranges", file=sys.stderr)
            serve_stream(args.arch, n_requests=args.requests,
                         rate=args.rate, max_seqs=args.max_seqs,
                         num_pages=args.num_pages, smoke=args.smoke,
                         attn_backend=backend, seed=args.seed,
                         prefill_chunk=args.prefill_chunk,
                         shards=args.shards,
                         prefix_cache=args.prefix_cache,
                         swap_bytes=args.swap_bytes,
                         kv_dtype=args.kv_dtype,
                         route_policy=args.route_policy)
        else:
            serve(args.arch, batch=args.batch or 4,
                  prompt_len=args.prompt_len or 64, gen=args.gen or 32,
                  smoke=args.smoke,
                  attn_backend=backend, seed=args.seed,
                  use_engine="never" if args.mode == "fixed" else "auto",
                  prefill_chunk=args.prefill_chunk, shards=args.shards,
                  prefix_cache=args.prefix_cache,
                  swap_bytes=args.swap_bytes,
                  kv_dtype=args.kv_dtype,
                  route_policy=args.route_policy)
    except ServingError as e:  # unsupported arch / impossible sizing;
        # genuine internal errors keep their tracebacks
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(2)


if __name__ == "__main__":
    main()
