"""Serving driver: batched prefill + greedy decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as S
from repro.models import transformer as T


def serve(arch: str, batch: int = 4, prompt_len: int = 64, gen: int = 32,
          smoke: bool = True, moba_impl: str = "reference", seed: int = 0):
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["cross_kv"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.num_image_tokens, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "encdec":
        extras["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.num_audio_frames, cfg.d_model)),
            cfg.dtype)

    max_len = prompt_len + gen
    caches = T.init_caches(cfg, batch, max_len,
                           dtype=jnp.dtype(cfg.dtype))
    prefill_fn = jax.jit(S.make_prefill_step(cfg, moba_impl=moba_impl),
                         donate_argnums=(2,))
    decode_fn = jax.jit(S.make_decode_step(cfg, moba_impl=moba_impl),
                        donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill_fn(params, prompts, caches, **extras)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, caches = decode_fn(params, tok, caches, **extras)
        out.append(tok)
    t_decode = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"prefill: {batch}×{prompt_len} tokens in {t_prefill:.2f}s; "
          f"decode: {batch}×{gen} tokens in {t_decode:.2f}s "
          f"({batch * gen / max(t_decode, 1e-9):.1f} tok/s)")
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--moba-impl", default="reference")
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen=args.gen, smoke=args.smoke, moba_impl=args.moba_impl)


if __name__ == "__main__":
    main()
