"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; `dryrun.py` sets the 512-device XLA flag before
calling it.
"""
from __future__ import annotations


from repro.configs.base import MeshConfig
from repro.distributed.sharding import make_compat_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(shape=(2, 16, 16) if multi_pod else (16, 16),
                      axes=("pod", "data", "model") if multi_pod
                      else ("data", "model"))
