import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Collective/buffer breakdown for one dry-run cell (hillclimb tooling)."""

import argparse      # noqa: E402
import collections   # noqa: E402
import re            # noqa: E402

import repro.launch.roofline as RL                      # noqa: E402
from repro.launch.dryrun import build_lowered           # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402


def collective_breakdown(arch, shape, multi_pod=False, top=14,
                         backend="sp", **kw):
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, cfg = build_lowered(arch, shape, mesh, backend=backend,
                                 unroll=False, **kw)
    compiled = lowered.compile()
    text = compiled.as_text()
    ma = compiled.memory_analysis()
    print(f"temp {ma.temp_size_in_bytes/1e9:.2f} GB/dev")
    comps, entry = RL._split_computations(text)

    def trip(cond):
        c = RL._CONST_RE.findall(comps.get(cond, ""))
        return max(int(x) for x in c) if c else 1

    agg = collections.Counter()

    def visit(name, mult, seen):
        if name in seen:
            return
        body = comps.get(name, "")
        for line in body.splitlines():
            m = RL._COLL_RE.search(line)
            if m:
                kind = m.group(1)
                ty = line.split("=", 1)[1].split(kind)[0]
                nb = RL._shape_bytes(ty) * RL._MULT[kind]
                meta = re.search(r'op_name="[^/]*/([^"]{0,70})', line)
                agg[(kind, ty.strip()[:44],
                     meta.group(1)[:48] if meta else "?")] += nb * mult
        for wm in RL._WHILE_RE.finditer(body):
            cond, wbody = wm.groups()
            visit(wbody, mult * trip(cond), seen | {name})

    visit(entry, 1, frozenset())
    total = sum(agg.values())
    print(f"total collective payload {total/1e9:.1f} GB/dev "
          f"(t={total/RL.ICI_BW:.2f}s)")
    for (kind, ty, meta), nb in agg.most_common(top):
        print(f"  {nb/1e9:8.2f} GB  {kind:<18} {ty:<44} {meta}")
    return compiled


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()
    collective_breakdown(args.arch, args.shape, args.multi)
