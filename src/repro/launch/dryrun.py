import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell on
# placeholder devices, record memory_analysis / cost_analysis / collective
# schedule, and emit the roofline terms (launch/roofline.py).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --archs qwen3-0.6b \
#       --shapes train_4k --mesh single
#
# Two passes per cell (see EXPERIMENTS.md §Dry-run):
#   1. PRODUCTION compile — layer-group scan + inner-scan attention →
#      memory_analysis is the deployable footprint and the compile is the
#      sharding-coherence proof; collectives are counted from this pass
#      with while-body × trip-count multiplication (validated against an
#      unrolled compile to within 1%).
#   2. ACCOUNTING lower (no compile) — everything unrolled;
#      ``lowered.cost_analysis()`` gives exact *global* FLOPs/bytes (XLA
#      counts while-loop bodies only once, so scanned code can't be used
#      for FLOP accounting — measured 10-100× undercount).
#
# Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.base import (ASSIGNED_SHAPES, ShardingConfig,  # noqa: E402
                                TrainConfig)
from repro.distributed import sharding as shmod  # noqa: E402
from repro.launch import roofline as RL        # noqa: E402
from repro.launch import steps as S            # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api, transformer as T  # noqa: E402
from repro.optim import adamw                  # noqa: E402


# gradient-accumulation factor for train_4k (global batch 256): keeps
# per-microbatch activations within HBM for the large archs.  The FLOP
# accounting pass (unroll=True) runs without accumulation — identical math.
MICROBATCH = {
    "llama-3.2-vision-90b": 16,
    "qwen3-14b": 8,
    "codeqwen1.5-7b": 8,
    "moonshot-v1-16b-a3b": 4,
    "qwen2-moe-a2.7b": 2,
    "internlm2-1.8b": 2,
    "zamba2-1.2b": 2,
    "mamba2-780m": 2,
}


def _sds(shape_struct, sh):
    return jax.ShapeDtypeStruct(shape_struct.shape, shape_struct.dtype,
                                sharding=sh)


def build_lowered(arch: str, shape: str, mesh, *, backend: str,
                  unroll: bool, block_size: int = 128, top_k: int = 8,
                  key_conv_width: int = 0, remat: bool = True,
                  scfg: ShardingConfig = None, accum_in_loss: bool = False):
    """Lower one cell with the given impl/unroll choice."""
    cfg = configs.get_config(arch, moba=True, block_size=block_size,
                             top_k=top_k, key_conv_width=key_conv_width)
    info = ASSIGNED_SHAPES[shape]
    seq, batch, kind = info["seq_len"], info["global_batch"], info["kind"]
    scfg = scfg or ShardingConfig()

    specs = api.input_specs(cfg, shape)
    param_shapes = jax.eval_shape(
        lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))
    pspecs = shmod.param_specs(param_shapes, mesh, scfg)
    param_in = jax.tree.map(_sds, param_shapes, pspecs)
    bsh = S.batch_shardings(cfg, mesh, batch)

    with shmod.use_mesh(mesh, scfg):
        if kind == "train":
            tcfg = TrainConfig(global_batch_size=batch, seq_len=seq,
                               microbatch=0 if unroll
                               else MICROBATCH.get(arch, 0))
            step = S.make_train_step(cfg, tcfg, backend=backend,
                                     remat=remat, unroll=unroll,
                                     accum_in_loss=accum_in_loss)
            opt_shapes = jax.eval_shape(adamw.adamw_init, param_shapes)
            ospecs = shmod.param_specs(opt_shapes.mu, mesh, scfg)
            opt_in = adamw.AdamWState(
                jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P())),
                jax.tree.map(_sds, opt_shapes.mu, ospecs),
                jax.tree.map(_sds, opt_shapes.nu, ospecs))
            batch_in = {"tokens": jax.ShapeDtypeStruct(
                specs["tokens"].shape, jnp.int32, sharding=bsh["tokens"])}
            for extra in ("cross_kv", "src_embeds"):
                if extra in specs:
                    batch_in[extra] = _sds(specs[extra], bsh[extra])
            jitted = jax.jit(step, donate_argnums=(0, 1))
            return jitted.lower(param_in, opt_in, batch_in), cfg
        caches_shape = specs.get("caches") or jax.eval_shape(
            lambda: T.init_caches(cfg, batch, seq))
        csh = S.cache_shardings(caches_shape, cfg, mesh, batch,
                                long_context=(shape == "long_500k"))
        cache_in = jax.tree.map(_sds, caches_shape, csh)
        extras = {extra: _sds(specs[extra], bsh[extra])
                  for extra in ("cross_kv", "src_embeds") if extra in specs}
        if kind == "prefill":
            step = S.make_prefill_step(cfg, backend=backend,
                                       unroll=unroll)
            tok_in = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                          sharding=bsh["tokens"])
        else:
            step = S.make_decode_step(cfg, backend=backend,
                                      unroll=unroll)
            tok_in = jax.ShapeDtypeStruct((batch, 1), jnp.int32,
                                          sharding=bsh["token"])
        jitted = jax.jit(step, donate_argnums=(2,))
        return jitted.lower(param_in, tok_in, cache_in, **extras), cfg


def lower_cell(arch: str, shape: str, multi_pod: bool,
               block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0, remat: bool = True,
               verbose: bool = True, accounting: bool = True):
    """Two-pass lower+compile of one cell; returns a Roofline record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    kw = dict(block_size=block_size, top_k=top_k,
              key_conv_width=key_conv_width, remat=remat)

    # pass 1: production compile — layer-group scan + inner-scan attention
    # (deployable memory footprint; collectives counted with while-body ×
    # trip-count multiplication in roofline.collective_bytes)
    t0 = time.time()
    lowered, cfg = build_lowered(arch, shape, mesh, backend="sp",
                                 unroll=False, **kw)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # pass 2: accounting lower (exact global flops; no compile)
    flops_global = bytes_global = None
    if accounting:
        lowered2, _ = build_lowered(arch, shape, mesh,
                                    backend="sp_unrolled", unroll=True,
                                    **kw)
        ca2 = lowered2.cost_analysis()
        ca2 = ca2[0] if isinstance(ca2, list) else ca2
        flops_global = float(ca2.get("flops", 0.0))
        bytes_global = float(ca2.get("bytes accessed", 0.0))

    mf = S.model_flops(cfg, shape)
    rl = RL.analyze(arch, shape, mesh_name, chips, compiled, mf)
    if flops_global:
        rl = RL.Roofline(**{**rl.__dict__,
                            "flops_per_device": flops_global / chips,
                            "bytes_per_device": bytes_global / chips})
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[{arch} × {shape} × {mesh_name}] compiled in {t_compile:.1f}s")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB"
              f" temp={ma.temp_size_in_bytes/1e9:.2f}GB"
              f" out={ma.output_size_in_bytes/1e9:.2f}GB (per device)")
        print(f"  flops/dev={rl.flops_per_device:.3e}"
              f" bytes/dev={rl.bytes_per_device:.3e}")
        mb = {k: f"{v/1e6:.1f}MB" for k, v in rl.coll_breakdown.items()}
        print(f"  collectives/dev: {mb}")
        print(f"  terms: compute={rl.t_compute:.3e}s memory={rl.t_memory:.3e}s"
              f" collective={rl.t_collective:.3e}s -> {rl.bottleneck}-bound,"
              f" useful={rl.useful_flops_ratio:.2f},"
              f" roofline={100*rl.roofline_fraction:.1f}%")
    return rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="all")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--block-size", type=int, default=128)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--key-conv", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-accounting", action="store_true",
                    help="skip the FLOP-accounting pass (multi-pod proof "
                         "runs don't need it; the roofline table is "
                         "single-pod only)")
    args = ap.parse_args()

    archs = configs.ASSIGNED if args.archs == "all" else args.archs.split(",")
    shapes = list(ASSIGNED_SHAPES) if args.shapes == "all" \
        else args.shapes.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    rows, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                try:
                    rl = lower_cell(arch, shape, mp,
                                    block_size=args.block_size,
                                    top_k=args.top_k,
                                    key_conv_width=args.key_conv,
                                    accounting=not (args.no_accounting
                                                    or mp))
                    rows.append(rl)
                    with open(path, "w") as f:
                        json.dump(rl.to_dict(), f, indent=1)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                    if args.stop_on_error:
                        raise

    print()
    print(RL.format_table(rows))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print(f"\nall {len(rows)} cells lowered + compiled OK")


if __name__ == "__main__":
    main()
