"""End-to-end training driver with fault-tolerant checkpoint/auto-resume.

CPU-scale usage (runs a real training loop on synthetic data):

  PYTHONPATH=src python -m repro.launch.train --arch moba-340m --smoke \
      --steps 50 --batch 8 --seq 512 --ckpt-dir /tmp/run1 --resume auto

The same driver drives the production mesh when devices exist — sharding
comes from the same rules as the dry-run.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.monitor import HeartbeatMonitor
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import transformer as T
from repro.optim import adamw


def train(arch: str, steps: int = 50, batch: int = 8, seq: int = 512,
          smoke: bool = True, attn_backend: str = "sparse",
          moba_impl: Optional[str] = None,
          ckpt_dir: str = "", resume: str = "none",
          save_interval: int = 20, lr: float = 6e-4, seed: int = 0,
          microbatch: int = 0, log_every: int = 10,
          block_size: int = 0, top_k: int = 0, key_conv_width: int = 0,
          remat: bool = False, on_step=None, stop_at_step: int = 0,
          total_steps_override: int = 0):
    if moba_impl is not None:
        raise ValueError(
            f"train(moba_impl=...) was removed; pass "
            f"attn_backend={moba_impl!r} instead (same values — see "
            f"core.backends.resolve_backend_spec)")
    kw = {}
    if block_size:
        kw["block_size"] = block_size
    if top_k:
        kw["top_k"] = top_k
    if key_conv_width:
        kw["key_conv_width"] = key_conv_width
    cfg = (configs.get_smoke_config(arch) if smoke
           else configs.get_config(arch, **kw))
    horizon = total_steps_override or steps
    tcfg = TrainConfig(global_batch_size=batch, seq_len=seq,
                       learning_rate=lr, total_steps=horizon,
                       warmup_steps=max(horizon // 10, 1), seed=seed,
                       microbatch=microbatch)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))

    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.adamw_init(params)
    start_step = 0
    mgr: Optional[CheckpointManager] = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        if resume in ("auto", "latest") and mgr.latest_step() is not None:
            tree = {"params": params, "mu": opt_state.mu,
                    "nu": opt_state.nu}
            tree, extra, ck_step = mgr.restore(tree)
            params = tree["params"]
            opt_state = adamw.AdamWState(
                jnp.asarray(ck_step, jnp.int32), tree["mu"], tree["nu"])
            start_step = extra.get("data_step", ck_step)
            print(f"[resume] restored step {ck_step} from {ckpt_dir}")

    # full spec strings allowed, e.g. "flash:compiled,flat,kb_tile=64" —
    # options apply process-wide to the named backend instance
    from repro.core import backends as B
    backend = B.resolve_backend_spec(attn_backend, default="sparse")
    step_fn = jax.jit(S.make_train_step(cfg, tcfg, backend=backend,
                                        remat=remat),
                      donate_argnums=(0, 1))

    extras = {}
    if cfg.family == "vlm":
        extras["cross_kv"] = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (batch, cfg.num_image_tokens, cfg.d_model)), cfg.dtype)
    if cfg.family == "encdec":
        extras["src_embeds"] = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (batch, cfg.num_audio_frames, cfg.d_model)), cfg.dtype)

    losses = []
    t0 = time.time()
    monitor = HeartbeatMonitor(
        on_straggler=lambda st, dt, med: print(
            f"[monitor] straggler step {st}: {dt:.2f}s vs median "
            f"{med:.2f}s"))
    end = min(stop_at_step, steps) if stop_at_step else steps
    for step in range(start_step, end):
        batch_np = data.batch_at(step)
        b = {"tokens": jnp.asarray(batch_np["tokens"])}
        b.update(extras)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.beat(step)
        if on_step:
            on_step(step, loss)
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics.get('lr', 0)):.2e} "
                  f"gnorm {float(metrics.get('grad_norm', 0)):7.3f} "
                  f"[{dt:6.1f}s]")
        if mgr and ((step + 1) % save_interval == 0 or step == end - 1):
            mgr.save(step + 1,
                     {"params": params, "mu": opt_state.mu,
                      "nu": opt_state.nu},
                     extra={"data_step": step + 1,
                            "loss": loss, "arch": arch})
    if mgr:
        mgr.wait()
    if monitor.straggler_steps:
        print(f"[monitor] summary: {monitor.summary()}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moba-340m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--moba-impl", default=None,
                    help=argparse.SUPPRESS)   # removed: structured error
    ap.add_argument("--attn-backend", default="sparse",
                    help="backend spec, e.g. sparse | flash:compiled | "
                         "flash:flat | flash:grouped,kb_tile=64 "
                         "(see core.backends.resolve_backend_spec)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--save-interval", type=int, default=20)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--block-size", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--key-conv", type=int, default=0)
    args = ap.parse_args()
    if args.moba_impl is not None:
        ap.error(f"--moba-impl was removed; use "
                 f"--attn-backend {args.moba_impl} (same values)")
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          smoke=args.smoke, attn_backend=args.attn_backend,
          ckpt_dir=args.ckpt_dir, resume=args.resume,
          save_interval=args.save_interval, lr=args.lr, seed=args.seed,
          microbatch=args.microbatch, block_size=args.block_size,
          top_k=args.top_k, key_conv_width=args.key_conv)


if __name__ == "__main__":
    main()
