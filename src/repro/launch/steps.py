"""Step-function builders + input/parameter sharding specs shared by
train.py, serve.py and dryrun.py."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ASSIGNED_SHAPES, ModelConfig, TrainConfig
from repro.distributed import sharding as shmod
from repro.models import api
from repro.models import transformer as T
from repro.optim import adamw


# ------------------------------------------------------------- step makers
def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    backend: str = "sparse", remat: bool = True,
                    unroll: bool = False, accum_in_loss: bool = False):
    """``accum_in_loss``: gradient accumulation expressed INSIDE the loss
    (scan over rematted microbatch chunks) so the cross-data gradient
    reduction happens ONCE per step instead of once per microbatch —
    measured 2.35 TB → 147 GB of grad all-reduce on llama-90B train_4k."""
    lr_fn = adamw.cosine_schedule(tcfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return T.lm_loss(p, batch, cfg, backend=backend,
                             remat=remat, unroll=unroll)

        if accum_in_loss and tcfg.microbatch and tcfg.microbatch > 1:
            m = tcfg.microbatch
            mb = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]),
                batch)

            def accum_loss(p):
                @jax.checkpoint
                def body(carry, batch_i):
                    l, _ = T.lm_loss(p, batch_i, cfg, backend=backend,
                                     remat=remat, unroll=unroll)
                    return carry + l / m, None

                total, _ = jax.lax.scan(body,
                                        jnp.zeros((), jnp.float32), mb)
                return total, {}

            (loss, metrics), grads = jax.value_and_grad(
                accum_loss, has_aux=True)(params)
        elif tcfg.microbatch and tcfg.microbatch > 1:
            m = tcfg.microbatch

            def micro(batch_i):
                def lf(p):
                    return T.lm_loss(p, batch_i, cfg, backend=backend,
                                     remat=remat, unroll=unroll)
                return jax.value_and_grad(lf, has_aux=True)(params)

            mb = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]),
                batch)

            def acc(carry, batch_i):
                (l, a), g = micro(batch_i)
                cl, cg = carry
                return (cl + l / m,
                        jax.tree.map(lambda x, y: x + y / m, cg, g)), None

            # derive the accumulator from params so it inherits the
            # FSDP sharding: per-microbatch grad sync then lowers to a
            # shard-sized reduce-scatter instead of a full all-reduce.
            zero_g = jax.tree.map(
                lambda p: (p * 0).astype(jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero_g), mb)
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw.adamw_update(params, grads,
                                                   opt_state, tcfg, lr_fn)
        out = {"loss": loss}
        out.update(om)
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, backend: str = "sparse",
                      unroll: bool = False):
    def prefill_step(params, tokens, caches, cross_kv=None,
                     src_embeds=None):
        ck = cross_kv
        if cfg.num_encoder_layers and src_embeds is not None:
            ck = T.apply_encoder(params, src_embeds, cfg,
                                 backend=backend, unroll=unroll)
        logits, new_caches = T.prefill(params, tokens, cfg, caches,
                                       backend=backend, cross_kv=ck,
                                       unroll=unroll)
        return logits[:, -1:], new_caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, backend: str = "reference",
                     unroll: bool = False):
    def decode_step(params, token, caches, cross_kv=None, src_embeds=None):
        ck = cross_kv
        if cfg.num_encoder_layers and src_embeds is not None:
            # encoder output is precomputed at prefill in real serving; the
            # stub keeps the decode cell self-contained.
            ck = T.apply_encoder(params, src_embeds, cfg,
                                 backend=backend, unroll=unroll)
        logits, new_caches = T.decode_step(params, token, cfg, caches,
                                           backend=backend, cross_kv=ck,
                                           unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_tok, new_caches

    return decode_step


def _as_route_map(route_map):
    """Freeze a profile's ``{"slot_i": (n_groups, H)}`` head budgets into
    int32 device constants.  Embedded in the step closures (not traced
    arguments), they are jit/shard_map-replicated constants — every step
    replays exactly the profile's routing decisions (DESIGN.md §8)."""
    if route_map is None:
        return None
    return {k: jnp.asarray(v, jnp.int32) for k, v in route_map.items()}


def make_paged_prefill_step(cfg: ModelConfig, backend: str = "reference",
                            chunked: bool = False, route_map=None):
    """Ragged prefill into a paged cache: tokens (B, L) right-padded with
    per-row valid length ``q_len``; rows with q_len == 0 are padding.
    ``kv_len`` gives each row's pre-step cache length (all zeros for
    one-shot prefill; chunk offsets under chunked prefill) and ``slots``
    maps prefill rows to scheduler sequence slots (for the per-slot
    key-conv ring buffer; -1 on padding rows).  ``chunked=True``
    (static) selects the chunk-aware attention path that sees earlier
    chunks through the block table.  ``route_map`` carries a calibrated
    adaptive-routing profile's per-head top_k budgets (None = static).
    Returns (sampled next token (B,) — meaningful only for rows whose
    prompt is now fully cached, new caches)."""
    rmap = _as_route_map(route_map)

    def prefill_step(params, tokens, caches, block_table, kv_len, q_len,
                     slots, active):
        page_state = {"block_table": block_table, "kv_len": kv_len,
                      "q_len": q_len, "slots": slots, "active": active,
                      "chunked": chunked}
        positions = (kv_len[:, None] + jnp.arange(tokens.shape[1])
                     if chunked else None)
        logits, new_caches = T.prefill(params, tokens, cfg, caches,
                                       backend=backend,
                                       page_state=page_state,
                                       positions=positions,
                                       route_map=rmap)
        last = jnp.maximum(q_len - 1, 0)[:, None, None]      # (B,1,1)
        lg = jnp.take_along_axis(logits, last, axis=1)[:, 0]  # (B,V)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32), new_caches

    return prefill_step


def make_paged_decode_step(cfg: ModelConfig, backend: str = "reference",
                           route_map=None):
    """One continuous-batching decode step over all sequence slots:
    token (B,), per-slot pre-step lengths kv_len (B,), active mask (B,).
    ``route_map`` as in :func:`make_paged_prefill_step`.
    Returns (next token (B,), new caches)."""
    rmap = _as_route_map(route_map)

    def decode_step(params, token, caches, block_table, kv_len, active):
        page_state = {"block_table": block_table, "kv_len": kv_len,
                      "q_len": active.astype(jnp.int32), "active": active}
        logits, new_caches = T.decode_step(params, token[:, None], cfg,
                                           caches, backend=backend,
                                           page_state=page_state,
                                           route_map=rmap)
        return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                new_caches)

    return decode_step


# ------------------------------------------------------- sharded serving
def _shard_over_data(fn, mesh, n_host_args: int):
    """Wrap a per-shard step so the whole fleet runs as ONE jitted
    shard_map over the mesh ``data`` axis (DESIGN.md §7).

    Every argument after ``params`` carries a leading shard dim equal to
    the data-axis size; the body strips its local slice (leading dim 1),
    runs the unmodified single-host step, and re-stacks.  Params are
    replicated (spec ``P()``); the attention math inside is mesh-free
    (``Capabilities.sharded``), so no collective crosses shards — decode
    for S shards costs one dispatch instead of S."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(params, *args):
        tok, new_caches = fn(params,
                             *jax.tree.map(lambda x: x[0], list(args)))
        return tok[None], jax.tree.map(lambda x: x[None], new_caches)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(),) + (P("data"),) * n_host_args,
                     out_specs=(P("data"), P("data")), check_rep=False)


def make_sharded_paged_prefill_step(cfg: ModelConfig, mesh,
                                    backend: str = "reference",
                                    chunked: bool = False,
                                    route_map=None):
    """Sharded :func:`make_paged_prefill_step`: every array argument
    gains a leading shard dim (S, ...) laid out over ``data``.  The
    adaptive ``route_map`` is a closure constant of the inner step, so
    it is replicated across shards — every shard routes from the same
    profile (shard-count invariance, pinned by test)."""
    return _shard_over_data(
        make_paged_prefill_step(cfg, backend=backend, chunked=chunked,
                                route_map=route_map),
        mesh, n_host_args=7)


def make_sharded_paged_decode_step(cfg: ModelConfig, mesh,
                                   backend: str = "reference",
                                   route_map=None):
    """Sharded :func:`make_paged_decode_step`: one jitted shard_map
    advances every shard's decode batch in a single dispatch."""
    return _shard_over_data(
        make_paged_decode_step(cfg, backend=backend, route_map=route_map),
        mesh, n_host_args=5)


# -------------------------------------------------------------- shardings
def _dp(mesh: Mesh):
    return shmod.data_axes(mesh)


def _div(n: int, mesh: Mesh, axes) -> bool:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return n % size == 0


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch: int) -> Dict:
    dp = _dp(mesh)
    bspec = dp if _div(batch, mesh, dp) else None
    tok = NamedSharding(mesh, P(bspec, None))
    out = {"tokens": tok, "token": tok}
    if cfg.family == "vlm":
        out["cross_kv"] = NamedSharding(mesh, P(bspec, None, None))
    if cfg.family == "encdec":
        out["src_embeds"] = NamedSharding(mesh, P(bspec, None, None))
    return out


def cache_shardings(caches_shape, cfg: ModelConfig, mesh: Mesh,
                    batch: int, long_context: bool = False):
    """Leaf-name-driven cache shardings. Long-context (batch 1) shards the
    sequence dim over every axis (context parallelism)."""
    dp = _dp(mesh)
    bspec = dp if _div(batch, mesh, dp) else None
    seq_axes = (dp + ("model",)) if long_context and bspec is None \
        else "model"

    def spec_of(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v"):
            return P(None, bspec, None, seq_axes, None)  # leading scan dim
        if name == "ssm":
            return P(None, bspec, "model", None, None)
        if name in ("conv",):
            return P(None, bspec, None, "model")
        if name == "key_conv_state":
            return P(None, bspec, None, None, None)
        if name == "centroids":
            return P(None, bspec, None, seq_axes, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
    specs = [NamedSharding(mesh, spec_of(path, leaf))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (fwd) with N = active non-dup
    params (MoE: only routed top-k + shared active)."""
    info = ASSIGNED_SHAPES[shape]
    n = api.active_param_count(cfg)
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model  # lookup table has ~no matmul
    if info["kind"] == "train":
        return 6.0 * n * info["seq_len"] * info["global_batch"]
    if info["kind"] == "prefill":
        return 2.0 * n * info["seq_len"] * info["global_batch"]
    return 2.0 * n * info["global_batch"]  # decode: one token per seq


def eval_shapes_with_sharding(fn, mesh, *specs_args):
    """eval_shape + attach NamedShardings (helper for dryrun)."""
    shapes = jax.eval_shape(fn, *specs_args)
    return shapes
