"""Host-side radix tree over token-id prefixes at page granularity.

One tree node = one physical page plus the token ids whose KV it caches
(up to ``page_size``; the last node of an inserted prefix may be
partial).  Because one KV page is exactly one routable MoBA block, a
matched page carries its cached centroid for free — sharing a prefix
deduplicates both KV storage *and* the router's query-key affinity work.

The tree never owns device memory: it holds one refcount per referenced
page in the scheduler's :class:`~repro.serving.scheduler.PagePool`, so a
page stays resident while either the tree or any running sequence maps
it, and :meth:`evict` can only drop pages nothing else references
(``refcount == 1``).  All bookkeeping is pure host-side numpy/dict work;
the caller (scheduler) decides when to take additional refs for the
sequences it admits onto matched pages.

Matching semantics:

  * full-page steps require exact ``page_size``-token content equality
    (an O(1) dict hop per page on the token bytes);
  * one optional trailing *partial* match takes the longest common
    prefix with the best child — the caller must copy-on-write that
    page before writing into it, since its tail tokens diverge;
  * ``full_only=True`` suppresses the partial step (key-conv configs
    restore ring state from page-end tails, which only exist for fully
    written pages).

Insertion dedups by content: re-inserting an existing prefix touches
LRU clocks and takes no new pages; a node holding a partial page is
*upgraded* in place when a fuller copy of the same content arrives
(the old page loses the tree's ref, the fuller one gains it).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


def _lcp(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common prefix of two int token arrays."""
    m = min(len(a), len(b))
    if m == 0:
        return 0
    neq = a[:m] != b[:m]
    return int(np.argmax(neq)) if neq.any() else m


class _Node:
    __slots__ = ("tokens", "page", "children", "parent", "last_used")

    def __init__(self, tokens: np.ndarray, page: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens            # int32 (count,), count <= page_size
        self.page = page                # physical page id (-1 = root)
        self.children: Dict[bytes, "_Node"] = {}
        self.parent = parent
        self.last_used = 0


class PrefixTree:
    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node(np.zeros((0,), np.int32), -1, None)
        self._clock = 0                 # logical LRU clock
        self.evictions = 0

    def __len__(self) -> int:
        """Number of pages the tree references."""
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------- match
    def match(self, tokens: np.ndarray, max_tokens: Optional[int] = None,
              full_only: bool = False, touch: bool = True
              ) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: (pages, n_tokens).

        Walks exact full-page hops, then (unless ``full_only``) one
        partial hop on the best longest-common-prefix child; when
        ``n_tokens % page_size != 0`` the last returned page is that
        partially-matched page.  Takes no refs — the caller refs the
        pages it decides to map.  ``touch=False`` leaves LRU clocks
        alone (router peeks across shards must not refresh them)."""
        toks = np.asarray(tokens, np.int32)
        limit = len(toks) if max_tokens is None else min(len(toks),
                                                         max_tokens)
        ps = self.page_size
        node, pages, matched = self.root, [], 0
        while matched + ps <= limit:
            child = node.children.get(toks[matched:matched + ps].tobytes())
            if child is None or len(child.tokens) < ps:
                break
            pages.append(child.page)
            matched += ps
            node = child
            if touch:
                child.last_used = self._tick()
        if not full_only and matched < limit:
            rem = toks[matched:limit]
            best, best_len = None, 0
            for child in node.children.values():
                m = _lcp(child.tokens, rem)
                if m > best_len:
                    best, best_len = child, m
            if best is not None:
                pages.append(best.page)
                matched += best_len
                if touch:
                    best.last_used = self._tick()
        return pages, matched

    def match_len(self, tokens: np.ndarray,
                  max_tokens: Optional[int] = None,
                  full_only: bool = False) -> int:
        """LRU-neutral match length (router shard-affinity peek)."""
        return self.match(tokens, max_tokens, full_only, touch=False)[1]

    # ------------------------------------------------------------ insert
    def insert(self, tokens: np.ndarray, pages: List[int], alloc) -> None:
        """Register ``pages`` as caching the prefix ``tokens``.

        ``len(pages) == ceil(len(tokens)/page_size)``; only the last page
        may be partial.  Pages whose content the tree already holds are
        deduped (no new ref); a held partial page is upgraded in place
        when ``tokens`` extends it.  Each newly referenced page gets one
        ``alloc.ref``; an upgraded-away page loses its tree ref."""
        toks = np.asarray(tokens, np.int32)
        ps = self.page_size
        node = self.root
        for j, page in enumerate(pages):
            chunk = toks[j * ps:(j + 1) * ps]
            key = chunk.tobytes()
            child = node.children.get(key)
            if child is None:
                # an existing child already covering chunk (chunk is a
                # prefix of its tokens) also dedups; a *partial* child
                # that chunk extends is upgraded to the fuller page
                covering = upgrade = None
                for c in node.children.values():
                    m = _lcp(c.tokens, chunk)
                    if m == len(chunk) and len(c.tokens) >= len(chunk):
                        covering = c
                        break
                    if m == len(c.tokens) and len(c.tokens) < len(chunk):
                        upgrade = c
                if covering is not None:
                    child = covering
                elif upgrade is not None:
                    del node.children[upgrade.tokens.tobytes()]
                    alloc.deref(upgrade.page)
                    upgrade.tokens = chunk.copy()
                    upgrade.page = page
                    alloc.ref(page)
                    node.children[key] = upgrade
                    child = upgrade
                else:
                    child = _Node(chunk.copy(), page, node)
                    alloc.ref(page)
                    node.children[key] = child
            child.last_used = self._tick()
            node = child

    # ------------------------------------------------------------- evict
    def evict(self, alloc, n: int) -> int:
        """Drop up to ``n`` least-recently-used leaf pages that only the
        tree references (``refcount == 1``), returning each to the free
        list.  Interior nodes become evictable as their subtrees drain.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n:
            victims = [node for node in self._iter()
                       if not node.children
                       and alloc.refcount(node.page) == 1]
            if not victims:
                break
            victim = min(victims, key=lambda nd: nd.last_used)
            del victim.parent.children[victim.tokens.tobytes()]
            alloc.deref(victim.page)
            freed += 1
            self.evictions += 1
        return freed

    def _iter(self):
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())
