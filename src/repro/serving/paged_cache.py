"""Paged KV cache with a per-page centroid cache (device side, pure jax).

A page holds ``page_size`` tokens of K and V for every kv head of one
layer slot.  ``page_size`` equals the MoBA ``block_size``, so **one page
is exactly one routable block**: the per-page centroid cache doubles as
the decode routing table, and reading it costs O(N/B·d) instead of the
O(N·d) full-cache centroid recompute the old decode path paid per step.

Layout: pools are token-major ``(num_pages, page_size, hkv, dh)`` so the
flat ``(num_pages*page_size, hkv, dh)`` scatter/gather view used by the
append paths is a free reshape, not a transpose-copy.  Invalid writes
(padded rows, unassigned pages) are routed to the out-of-bounds slot
``num_pages*page_size`` and dropped by the scatter — no dump page needed.

Sequences are described *outside* the pool by a block table: row i maps
sequence i's logical page j to a physical page id (−1 = unassigned).
Block tables and sequence lengths live on the host (scheduler) and are
passed into the jitted steps as small int32 arrays each step.

Centroid semantics match the dense cache exactly (tests assert this):
  * prefill recomputes each touched page's centroid from the stored keys
    (same math as :func:`repro.core.routing.block_centroids`);
  * decode folds the new key in with one rank-1 update
    ``c ← (c·m + k)/(m+1)`` — amortized O(d) per token.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import quantization as Q

# leaves indexed by physical page id on their (first non-shard) axis —
# the unit that page-granular ops (COW copy, swap save/restore) move.
# ``scales_k``/``scales_v`` (quantized pools only) live here so COW
# copies and host swap carry payload + scales atomically for free.
# ``key_conv_state`` is per sequence *slot*, not per page, and moves via
# the ring-row helpers instead.
PAGE_LEAVES = ("pages_k", "pages_v", "scales_k", "scales_v",
               "centroids", "key_conv_tails")


def resolve_page_size(cfg: ModelConfig) -> int:
    """Page size = MoBA block size when any layer routes; else 16."""
    a = cfg.attention
    if a.moba is not None and any(k == "moba" for k in cfg.layer_pattern):
        return a.moba.block_size
    return 16


def init_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                   with_centroids: bool, dtype=jnp.bfloat16,
                   max_seqs: int = 0, prefix_tails: bool = False,
                   kv_dtype: str = "fp32") -> Dict:
    """One layer slot's pool.  MoBA slots of key-conv models additionally
    carry a per-sequence-slot ring buffer ``key_conv_state`` of the last
    ``key_conv_width - 1`` raw (post-RoPE, pre-conv) keys, sized by
    ``max_seqs`` — the single-step decode conv and chunked prefill both
    read/write it by scheduler slot id (DESIGN.md §4).

    ``prefix_tails`` (prefix-cache engines of key-conv models) adds a
    per-*page* companion ``key_conv_tails`` holding the raw keys of each
    page's last ``width - 1`` positions: when admission maps a sequence
    onto cached pages, its ring row is loaded from the last matched
    page's tail, so the suffix prefill convs with exactly the state a
    contiguous prefill would have carried (docs/serving.md).

    ``kv_dtype`` of ``"int8"``/``"fp8"`` stores the K/V payload
    quantized with per-(page, kv head) fp32 ``scales_k``/``scales_v``
    leaves (init 1.0 so dequantizing a fresh page is a no-op); routing
    state — centroids, key-conv ring buffers and tails — stays at full
    precision regardless (``core/quantization.py``).  ``"fp32"`` keeps
    the pre-quantization layout byte-for-byte: pages at ``dtype``, no
    scales leaves."""
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_dtype not in Q.KV_DTYPES:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"expected one of {Q.KV_DTYPES}")
    # only the page payload is quantized; key-conv ring buffers / tails
    # below keep the compute ``dtype`` (they feed the fp32 router)
    pg_dtype = dtype if kv_dtype == "fp32" else Q.payload_dtype(kv_dtype)
    pool = {"pages_k": jnp.zeros((num_pages, page_size, hkv, dh), pg_dtype),
            "pages_v": jnp.zeros((num_pages, page_size, hkv, dh), pg_dtype)}
    if kv_dtype != "fp32":
        pool["scales_k"] = jnp.ones((num_pages, hkv), jnp.float32)
        pool["scales_v"] = jnp.ones((num_pages, hkv), jnp.float32)
    if with_centroids:
        pool["centroids"] = jnp.zeros((num_pages, hkv, dh), jnp.float32)
        a = cfg.attention
        width = a.moba.key_conv_width if a.moba is not None else 0
        if width and max_seqs:
            pool["key_conv_state"] = jnp.zeros(
                (max_seqs, hkv, width - 1, dh), dtype)
            if prefix_tails:
                pool["key_conv_tails"] = jnp.zeros(
                    (num_pages, hkv, width - 1, dh), dtype)
    return pool


def is_paged(cache) -> bool:
    return cache is not None and "pages_k" in cache


def shard_pools(caches, mesh, n_shards: int):
    """Stack ``n_shards`` copies of a (zero-initialised) cache pytree
    along a new leading shard axis and lay the result out over the
    mesh's ``data`` axis — each shard owns its own pool slice (pages,
    centroid cache, key-conv ring buffers); nothing is replicated.

    The stacked layout is what the sharded engine's ``shard_map`` step
    functions split: inside the body each device sees leading dim 1,
    strips it, and runs the unmodified single-host step (DESIGN.md §7).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = NamedSharding(mesh, P("data"))
    return jax.tree.map(
        lambda x: jax.device_put(
            jnp.broadcast_to(x[None], (n_shards,) + x.shape), spec),
        caches)


def paged_append_decode(cache: Dict, block_table: jax.Array,
                        kv_len: jax.Array, active: jax.Array,
                        k_new: jax.Array, v_new: jax.Array) -> Dict:
    """Write one token per active sequence at position ``kv_len[i]``.

    k_new/v_new: (B, hkv, 1, dh) in compute dtype.  Updates the written
    page's centroid incrementally.  Inactive rows write nothing.

    Quantized pools (``scales_k`` present) requantize the whole tail
    page read-modify-write: gather → dequantize → insert the token →
    amax over the now-valid positions → scatter payload + scale back.
    The centroid update below is untouched — it folds the *fp32*
    incoming key into the old centroid, never reading the pool, so
    routing state is bitwise identical across ``kv_dtype`` modes.
    """
    pk, pv = cache["pages_k"], cache["pages_v"]
    num_pages, ps, hkv, dh = pk.shape
    page_idx = kv_len // ps
    off = kv_len % ps
    phys = jnp.take_along_axis(block_table, page_idx[:, None], axis=1)[:, 0]
    ok = active & (phys >= 0)
    tok_k = k_new[:, :, 0]                                   # (B,hkv,dh)
    tok_v = v_new[:, :, 0]
    if "scales_k" in cache:
        kv_dt = Q.kv_dtype_of(pk.dtype)
        ph = jnp.maximum(phys, 0)
        pidx = jnp.where(ok, phys, num_pages)
        onehot = jnp.arange(ps)[None, :] == off[:, None]     # (B,ps)
        vmask = jnp.arange(ps)[None, :] <= off[:, None]      # valid incl new

        def requant(pool, scales, tok):
            page = Q.dequantize(pool[ph],
                                scales[ph][:, None, :, None])  # (B,ps,h,d)
            page = jnp.where(onehot[:, :, None, None],
                             tok.astype(jnp.float32)[:, None], page)
            scale = Q.compute_scale(page, (1, 3), kv_dt,
                                    where=vmask[:, :, None, None])  # (B,h)
            payload = Q.quantize(page, scale[:, None, :, None], kv_dt)
            return (pool.at[pidx].set(payload, mode="drop"),
                    scales.at[pidx].set(scale, mode="drop"))

        new_pk, new_sk = requant(pk, cache["scales_k"], tok_k)
        new_pv, new_sv = requant(pv, cache["scales_v"], tok_v)
        new = dict(cache, pages_k=new_pk, pages_v=new_pv,
                   scales_k=new_sk, scales_v=new_sv)
    else:
        slot = jnp.where(ok, phys * ps + off, num_pages * ps)
        flat_k = pk.reshape(num_pages * ps, hkv, dh)
        flat_v = pv.reshape(num_pages * ps, hkv, dh)
        flat_k = flat_k.at[slot].set(tok_k.astype(pk.dtype), mode="drop")
        flat_v = flat_v.at[slot].set(tok_v.astype(pv.dtype), mode="drop")
        new = dict(cache,
                   pages_k=flat_k.reshape(num_pages, ps, hkv, dh),
                   pages_v=flat_v.reshape(num_pages, ps, hkv, dh))
    if "centroids" in cache:
        cents = cache["centroids"]                           # (P,hkv,dh) f32
        m = off.astype(jnp.float32)[:, None, None]           # tokens in page
        old = cents[jnp.maximum(phys, 0)]                    # (B,hkv,dh)
        upd = (old * m + tok_k.astype(jnp.float32)) / (m + 1.0)
        new["centroids"] = cents.at[jnp.where(ok, phys, num_pages)].set(
            upd, mode="drop")
    return new


def paged_append_prefill(cache: Dict, block_table: jax.Array,
                         q_len: jax.Array, k_new: jax.Array,
                         v_new: jax.Array,
                         kv_len: Optional[jax.Array] = None) -> Dict:
    """Scatter a right-padded ragged prompt chunk into its pages.

    k_new/v_new: (B, hkv, L, dh); row i's valid tokens occupy absolute
    positions [kv_len[i], kv_len[i] + q_len[i]).  ``kv_len`` of None (or
    zeros) is a fresh one-shot prefill; non-zero offsets are chunked
    prefill continuations writing into a partially-filled tail page.
    Every page the chunk touches gets its centroid recomputed from the
    stored keys — for a tail page that earlier chunks started, the
    recompute reads those chunks' keys back from the pool, so the result
    is identical to a one-shot prefill of the whole prefix.

    Quantized pools stage the touched pages in fp32 — prior pool tokens
    dequantized, the incoming chunk scattered over them — then
    requantize each touched page whole (amax over its valid tokens) and
    scatter payload + scales back.  Centroids are computed *from the
    staging view* with the exact masked reduce the fp32 path uses, so
    any page fully written by this call (every page of a one-shot
    prefill) gets a bitwise-identical centroid; only a chunked/suffix
    tail page whose earlier tokens already live quantized in the pool
    sees quantization error in its centroid.
    """
    pk, pv = cache["pages_k"], cache["pages_v"]
    num_pages, ps, hkv, dh = pk.shape
    b, _, length, _ = k_new.shape
    npg = block_table.shape[1]
    if kv_len is None:
        kv_len = jnp.zeros((b,), jnp.int32)
    pos = kv_len[:, None] + jnp.arange(length)               # (B,L) abs pos
    logical = jnp.minimum(pos // ps, npg - 1)
    phys = jnp.take_along_axis(block_table, logical, axis=1)  # (B,L)
    valid = (jnp.arange(length)[None, :] < q_len[:, None]) & (phys >= 0)
    vals_k = k_new.transpose(0, 2, 1, 3).reshape(b * length, hkv, dh)
    vals_v = v_new.transpose(0, 2, 1, 3).reshape(b * length, hkv, dh)
    post = q_len + kv_len                                    # (B,)
    page_start = jnp.arange(npg) * ps
    cnt = jnp.clip(post[:, None] - page_start, 0, ps)
    touched = ((cnt > 0) & (block_table >= 0)
               & (page_start + ps > kv_len[:, None]))        # (B,npg)
    wmask = jnp.arange(ps)[None, None, :] < cnt[..., None]   # (B,npg,ps)
    idx = jnp.where(touched, block_table, num_pages).reshape(-1)

    if "scales_k" in cache:
        kv_dt = Q.kv_dtype_of(pk.dtype)
        tbl = jnp.maximum(block_table, 0)
        stage_slot = jnp.where(
            valid, (jnp.arange(b)[:, None] * npg + logical) * ps + pos % ps,
            b * npg * ps).reshape(-1)

        def stage_and_quant(pool, scales, vals):
            stage = Q.dequantize(pool[tbl],
                                 scales[tbl][:, :, None, :, None])
            stage = stage.reshape(b * npg * ps, hkv, dh).at[stage_slot].set(
                vals.astype(jnp.float32), mode="drop")
            stage = stage.reshape(b, npg, ps, hkv, dh)
            scale = Q.compute_scale(stage, (2, 4), kv_dt,
                                    where=wmask[:, :, :, None, None])
            payload = Q.quantize(stage, scale[:, :, None, :, None], kv_dt)
            return stage, (
                pool.at[idx].set(payload.reshape(b * npg, ps, hkv, dh),
                                 mode="drop"),
                scales.at[idx].set(scale.reshape(b * npg, hkv),
                                   mode="drop"))

        stage_k, (new_pk, new_sk) = stage_and_quant(
            pk, cache["scales_k"], vals_k)
        _, (new_pv, new_sv) = stage_and_quant(
            pv, cache["scales_v"], vals_v)
        new = dict(cache, pages_k=new_pk, pages_v=new_pv,
                   scales_k=new_sk, scales_v=new_sv)
        cent_src = stage_k                                   # (B,npg,ps,h,d)
    else:
        slot = jnp.where(valid, phys * ps + pos % ps,
                         num_pages * ps).reshape(-1)
        flat_k = pk.reshape(num_pages * ps, hkv, dh).at[slot].set(
            vals_k.astype(pk.dtype), mode="drop")
        flat_v = pv.reshape(num_pages * ps, hkv, dh).at[slot].set(
            vals_v.astype(pv.dtype), mode="drop")
        new_pk = flat_k.reshape(num_pages, ps, hkv, dh)
        new_pv = flat_v.reshape(num_pages, ps, hkv, dh)
        new = dict(cache, pages_k=new_pk, pages_v=new_pv)
        cent_src = new_pk[jnp.maximum(block_table, 0)]       # (B,npg,ps,h,d)
    if "centroids" in cache:
        sums = (cent_src.astype(jnp.float32)
                * wmask[..., None, None]).sum(axis=2)        # (B,npg,h,d)
        cent = sums / jnp.maximum(cnt, 1)[..., None, None].astype(
            jnp.float32)
        new["centroids"] = cache["centroids"].at[idx].set(
            cent.reshape(b * npg, hkv, dh), mode="drop")
    return new


def paged_gather_kv(cache: Dict, block_table: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Densify: (B, hkv, npg*ps, dh) K and V in logical token order.

    Positions past a sequence's length (and pages it never allocated)
    hold whatever the pool contains — callers mask with ``kv_len``.
    Quantized pools come back dequantized to fp32 (payload × per-page
    scale), so every densify consumer is dtype-oblivious.
    """
    pk, pv = cache["pages_k"], cache["pages_v"]
    num_pages, ps, hkv, dh = pk.shape
    b, npg = block_table.shape
    tbl = jnp.maximum(block_table, 0)

    def densify(pool, scales=None):
        g = pool[tbl]                                        # (B,npg,ps,h,d)
        if scales is not None:
            g = Q.dequantize(g, scales[tbl][:, :, None, :, None])
        return g.transpose(0, 3, 1, 2, 4).reshape(b, hkv, npg * ps, dh)

    return (densify(pk, cache.get("scales_k")),
            densify(pv, cache.get("scales_v")))


def swa_windowed_decode_attention(q: jax.Array, cache: Dict,
                                  block_table: jax.Array,
                                  kv_len: jax.Array, window: int,
                                  scale: Optional[float] = None
                                  ) -> jax.Array:
    """Decode-step sliding-window attention that gathers only the
    ``ceil(window/page_size)+1`` pages that can intersect the window
    (closing the DESIGN.md §4 open item): the per-step copy is bounded
    by O(window), not O(max_seq_len) densify-then-mask.

    q (B, H, 1, d); ``kv_len`` post-append lengths, so the query sits at
    position ``kv_len - 1`` and attends keys in ``(qpos-window, qpos]``.
    Numerics match the densified path exactly (same masked softmax over
    the same key set).  Rows with ``kv_len`` 0 return zeros.
    """
    from repro.core.attention import (NEG_INF, _apply_and_project,
                                      _grouped_scores)

    pk, pv = cache["pages_k"], cache["pages_v"]
    num_pages, ps, hkv, dh = pk.shape
    b, npg = block_table.shape
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    wpg = min(npg, -(-window // ps) + 1)
    qpos = kv_len - 1                                        # (B,)
    start = jnp.maximum(qpos - window + 1, 0) // ps          # first page
    logical = start[:, None] + jnp.arange(wpg)[None, :]      # (B,wpg)
    phys = jnp.take_along_axis(block_table,
                               jnp.minimum(logical, npg - 1), axis=1)
    ok = (logical < npg) & (phys >= 0)                       # (B,wpg)
    tbl = jnp.maximum(phys, 0)
    kg, vg = pk[tbl], pv[tbl]                                # (B,wpg,ps,h,d)
    if "scales_k" in cache:
        kg = Q.dequantize(kg, cache["scales_k"][tbl][:, :, None, :, None])
        vg = Q.dequantize(vg, cache["scales_v"][tbl][:, :, None, :, None])
    kg = kg.transpose(0, 3, 1, 2, 4).reshape(b, hkv, wpg * ps, dh)
    vg = vg.transpose(0, 3, 1, 2, 4).reshape(b, hkv, wpg * ps, dh)
    kpos = (logical[:, :, None] * ps
            + jnp.arange(ps)[None, None, :]).reshape(b, wpg * ps)
    mask = (jnp.repeat(ok, ps, axis=1)
            & (kpos <= qpos[:, None])
            & (qpos[:, None] - kpos < window))               # (B,wpg*ps)
    s = _grouped_scores(q, kg, scale)                        # (B,H,1,n)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, None, None], p, 0.0)
    return _apply_and_project(p, vg, q.dtype)


def gather_seq_centroids(cache: Dict, block_table: jax.Array) -> jax.Array:
    """Per-sequence centroid view (B, hkv, npg, dh) in logical order."""
    cents = cache["centroids"][jnp.maximum(block_table, 0)]  # (B,npg,h,d)
    return cents.transpose(0, 2, 1, 3)


# --------------------------------------------------------------------------
# page-granular cache ops (prefix cache / COW / swap preemption).
#
# ``caches`` here is the engine-level pytree ``{"slot_i": pool}`` whose
# leaves carry a leading layer-group dim (G, ...) — or (S, G, ...) for the
# sharded engine, selected by ``shard``.  These run OUTSIDE the jitted
# step functions, between the scheduler's plan and the step's first
# write; plain XLA scatter/gather ops are plenty (a handful of pages per
# event), and keeping them un-jitted avoids retrace churn on the ragged
# page counts.
# --------------------------------------------------------------------------

def _page_view(x, shard):
    return x if shard is None else x[shard]


@functools.partial(jax.jit, static_argnames=("shard",))
def _copy_pages_jit(caches, s, d, shard):
    def one(pool):
        new = dict(pool)
        for name in PAGE_LEAVES:
            if name in pool:
                x = pool[name]
                if shard is None:
                    new[name] = x.at[:, d].set(x[:, s])
                else:
                    xs = x[shard]
                    new[name] = x.at[shard].set(xs.at[:, d].set(xs[:, s]))
        return new

    return {k: one(v) for k, v in caches.items()}


def copy_pages(caches, src: List[int], dst: List[int],
               shard: Optional[int] = None):
    """Copy-on-write: duplicate physical pages ``src[i] -> dst[i]`` in
    every page-indexed leaf (K/V, centroid, key-conv tails), so a
    sequence diverging mid-page writes into its own copy.  Copying the
    centroid too keeps the page immediately routable — the suffix
    prefill then recomputes it from stored keys once it appends.  One
    jitted dispatch over all pools/leaves — the engine drains COWs one
    pair at a time, so the (1,)-shaped trace compiles once."""
    if not src:
        return caches
    return _copy_pages_jit(caches, jnp.asarray(src, jnp.int32),
                           jnp.asarray(dst, jnp.int32), shard)


def gather_pages_host(caches, pages: List[int],
                      shard: Optional[int] = None) -> Dict:
    """Snapshot physical pages to host numpy (swap-out): every
    page-indexed leaf sliced at ``pages``, keyed (slot_name, leaf)."""
    idx = jnp.asarray(pages, jnp.int32)
    out = {}
    for sname, pool in caches.items():
        for name in PAGE_LEAVES:
            if name in pool:
                x = _page_view(pool[name], shard)
                out[(sname, name)] = np.asarray(x[:, idx])
    return out


def scatter_pages_device(caches, pages: List[int], data: Dict,
                         shard: Optional[int] = None):
    """Swap-in: write a :func:`gather_pages_host` snapshot into the
    (freshly reserved) physical pages ``pages``."""
    idx = jnp.asarray(pages, jnp.int32)
    new = {}
    for sname, pool in caches.items():
        p2 = dict(pool)
        for name in PAGE_LEAVES:
            if name in pool:
                x = pool[name]
                vals = jnp.asarray(data[(sname, name)], x.dtype)
                if shard is None:
                    p2[name] = x.at[:, idx].set(vals)
                else:
                    p2[name] = x.at[shard].set(
                        x[shard].at[:, idx].set(vals))
        new[sname] = p2
    return new


def gather_ring_rows(caches, slot: int,
                     shard: Optional[int] = None) -> Dict:
    """Host snapshot of one sequence slot's key-conv ring row (empty
    dict for non-key-conv pools)."""
    out = {}
    for sname, pool in caches.items():
        if "key_conv_state" in pool:
            x = _page_view(pool["key_conv_state"], shard)
            out[(sname, "key_conv_state")] = np.asarray(x[:, slot])
    return out


def scatter_ring_rows(caches, slot: int, data: Dict,
                      shard: Optional[int] = None):
    new = {}
    for sname, pool in caches.items():
        p2 = pool
        if "key_conv_state" in pool:
            x = pool["key_conv_state"]
            vals = jnp.asarray(data[(sname, "key_conv_state")], x.dtype)
            if shard is None:
                x = x.at[:, slot].set(vals)
            else:
                x = x.at[shard].set(x[shard].at[:, slot].set(vals))
            p2 = dict(pool, key_conv_state=x)
        new[sname] = p2
    return new


def load_ring_from_tails(caches, slots: List[int], pages: List[int],
                         shard: Optional[int] = None):
    """Prefix-hit admission for key-conv models: sequence ``slots[i]``'s
    ring row becomes page ``pages[i]``'s raw-key tail — the last
    ``width - 1`` raw keys before the match boundary, exactly the state
    a contiguous prefill would have carried into the suffix."""
    if not slots:
        return caches
    sl = jnp.asarray(slots, jnp.int32)
    pg = jnp.asarray(pages, jnp.int32)
    new = {}
    for sname, pool in caches.items():
        p2 = pool
        if "key_conv_tails" in pool and "key_conv_state" in pool:
            ring, tails = pool["key_conv_state"], pool["key_conv_tails"]
            if shard is None:
                ring = ring.at[:, sl].set(
                    tails[:, pg].astype(ring.dtype))
            else:
                ring = ring.at[shard].set(ring[shard].at[:, sl].set(
                    tails[shard][:, pg].astype(ring.dtype)))
            p2 = dict(pool, key_conv_state=ring)
        new[sname] = p2
    return new


def update_key_conv_tails(cache: Dict, block_table: jax.Array,
                          kv_len: jax.Array, q_len: jax.Array,
                          k_raw: jax.Array) -> Dict:
    """Maintain the per-page raw-key tails through an append (runs
    inside the jitted step, right after the page write).

    k_raw (B, hkv, L, dh) are the *pre-conv* keys row i writes at
    absolute positions [kv_len[i], kv_len[i] + q_len[i]); any that land
    in a page's last ``width - 1`` positions are recorded in that page's
    tail slot.  Decode calls this with L == 1 and ``q_len`` the active
    mask.  Single-pool view — no layer-group dim (the step's scan
    strips it)."""
    tails = cache["key_conv_tails"]           # (P, hkv, depth, dh)
    num_pages, hkv, depth, dh = tails.shape
    ps = cache["pages_k"].shape[1]
    b, _, length, _ = k_raw.shape
    npg = block_table.shape[1]
    pos = kv_len[:, None] + jnp.arange(length)               # (B,L) abs
    logical = jnp.minimum(pos // ps, npg - 1)
    phys = jnp.take_along_axis(block_table, logical, axis=1)  # (B,L)
    ti = pos % ps - (ps - depth)                             # tail index
    valid = ((jnp.arange(length)[None, :] < q_len[:, None])
             & (phys >= 0) & (ti >= 0))
    slot = jnp.where(valid, phys * depth + ti,
                     num_pages * depth).reshape(-1)
    vals = k_raw.transpose(0, 2, 1, 3).reshape(b * length, hkv, dh)
    flat = tails.transpose(0, 2, 1, 3).reshape(
        num_pages * depth, hkv, dh)
    flat = flat.at[slot].set(vals.astype(tails.dtype), mode="drop")
    return dict(cache, key_conv_tails=flat.reshape(
        num_pages, depth, hkv, dh).transpose(0, 2, 1, 3))
