"""Sharded multi-host serving engine (DESIGN.md §7).

The single-host :class:`~repro.serving.engine.Engine` owns ONE page pool
and ONE scheduler; this module scales it over the mesh ``data`` axis
without changing any attention math:

  * every shard owns its own slice of the paged state — page pool,
    centroid cache, key-conv ring buffers — stacked along a leading
    shard dim and laid out over ``data`` (`paged_cache.shard_pools`);
  * a host-side :class:`Router` assigns each incoming request to the
    least-loaded shard, after which its whole lifetime (admission,
    growth, preemption, replay) is handled by that shard's own
    :class:`~repro.serving.scheduler.Scheduler`;
  * each engine step runs at most one jitted ``shard_map`` prefill and
    one jitted ``shard_map`` decode across ALL shards
    (`launch/steps.make_sharded_paged_*`): inside the body each device
    strips its local pool slice and runs the unmodified single-host
    step, so zero collectives cross shards and a request's greedy
    tokens are bit-identical to the single-host engine's
    (`tests/test_sharded_serving.py`);
  * a single request longer than one shard's pool cannot be paged — it
    falls back to context-parallel decode over the same devices
    (`distributed/moba_sp.moba_decode_cp`), routing on shard-local
    centroids from the dense cache's incremental centroid cache.

Prefill rows are padded to ONE bucket computed from the global longest
take via the pure function :func:`~repro.serving.engine.prefill_bucket`
— bucket sizes are shard-invariant by construction (asserted), so the
jit cache holds one prefill variant per bucket engine-wide instead of
fragmenting per shard.
"""
from __future__ import annotations

import collections
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingConfig
from repro.core import quantization as Q
from repro.distributed import sharding as shmod
from repro.launch import steps as S
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.engine import (EngineConfig, HostSwapStore, Prefix,
                                  admission_capability_check,
                                  build_decode_batch, build_prefill_batch,
                                  build_route_profile, drain_cache_ops,
                                  needs_key_conv, prefill_bucket,
                                  prefill_takes, record_prefill,
                                  resolve_engine_backend,
                                  resolve_pool_sizes, unsupported_reason)
from repro.serving.scheduler import (Request, Scheduler, ServingError,
                                     UnsupportedFeatureError)


class Router:
    """Host-side router over per-shard schedulers.

    ``pick`` prefers the shard whose prefix tree holds the longest
    cached prefix of the request (an LRU-neutral ``peek_prefix`` — each
    shard's tree is private, so affinity is what turns shared system
    prompts into cross-request page sharing), then the smallest
    page-demand ``load`` (committed + queued pages), ties broken by
    lowest shard id — fully deterministic for a given submission order,
    which the equivalence suite relies on.  Without the prefix cache
    every peek is 0 and this reduces to pure least-loaded routing.
    Returns −1 when no shard can serve it (context-parallel fallback or
    rejection is the engine's call)."""

    def __init__(self, scheds: Sequence[Scheduler]):
        self.scheds = scheds

    def pick(self, req: Request) -> int:
        fitting = [s for s, sch in enumerate(self.scheds) if sch.fits(req)]
        if not fitting:
            return -1
        return min(fitting, key=lambda s: (-self.scheds[s].peek_prefix(req),
                                           self.scheds[s].load, s))


class ShardedEngine:
    """Continuous-batching engine whose page pools are sharded over the
    mesh ``data`` axis.  ``ecfg`` sizes are PER SHARD (``max_seqs``
    slots and ``num_pages`` pages on every shard); total capacity is
    ``n_shards`` times that.  API mirrors :class:`Engine`:
    ``submit`` / ``step`` / ``run`` / ``stats`` (+ ``shard_stats``)."""

    def __init__(self, cfg: ModelConfig, params,
                 ecfg: Optional[EngineConfig] = None, n_shards: int = 2,
                 mesh=None):
        reason = unsupported_reason(cfg)
        if reason is not None:
            raise UnsupportedFeatureError(*reason)
        self.cfg = cfg
        self.ecfg = ecfg = ecfg or EngineConfig()
        self.attn_backend = resolve_engine_backend(
            ecfg.attn_backend, "sharded")
        if ecfg.dispatch_ahead < 0:
            raise ServingError(
                f"dispatch_ahead must be >= 0, got {ecfg.dispatch_ahead}")
        if mesh is None:
            if n_shards > len(jax.devices()):
                raise ServingError(
                    f"n_shards={n_shards} exceeds the {len(jax.devices())}"
                    f" visible devices; simulate with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N")
            mesh = shmod.make_compat_mesh((n_shards,), ("data",))
        if "data" not in mesh.axis_names:
            raise ServingError(
                f"sharded engine needs a 'data' mesh axis, got "
                f"{mesh.axis_names}")
        self.mesh = mesh
        self.n_shards = ns = mesh.shape["data"]
        # same admission query as Engine, additionally demanding the
        # backend's per-shard math is mesh-free (Capabilities.sharded)
        if ecfg.kv_dtype not in Q.KV_DTYPES:
            raise ServingError(
                f"unknown kv_dtype {ecfg.kv_dtype!r}; expected one of "
                f"{Q.KV_DTYPES}")
        from repro.core.adaptive import parse_route_policy
        try:
            route_mode, _ = parse_route_policy(ecfg.route_policy)
        except ValueError as e:
            raise UnsupportedFeatureError("route_policy", str(e)) from e
        admission_capability_check(cfg, self.attn_backend, sharded=True,
                                   kv_dtype=ecfg.kv_dtype,
                                   adaptive=route_mode != "static")
        self.page_size, self.pages_per_seq, self.num_pages = \
            resolve_pool_sizes(cfg, ecfg)
        # ONE routing profile, calibrated (or loaded) once and embedded
        # as a replicated closure constant of the shard_map steps —
        # every shard routes identically, so a request's tokens cannot
        # depend on which shard the router picked (shard invariance).
        # The context-parallel fallback (`_run_cp`, dense caches on the
        # ``sp`` backend) has no per-head budget plumbing and stays on
        # static routing — a documented limitation (docs/serving.md).
        self.route_profile, self._route_map = build_route_profile(
            cfg, params, ecfg.route_policy, self.pages_per_seq)
        self.params = jax.device_put(params, NamedSharding(mesh, P()))
        conv = needs_key_conv(cfg)
        if ecfg.prefix_cache and conv \
                and cfg.attention.moba.key_conv_width - 1 > self.page_size:
            raise ServingError(
                f"prefix cache needs key_conv_width - 1 "
                f"({cfg.attention.moba.key_conv_width - 1}) <= page_size "
                f"({self.page_size}): ring state restores from one "
                f"page's raw-key tail")
        base = T.init_paged_caches(cfg, self.num_pages, self.page_size,
                                   dtype=jnp.dtype(cfg.dtype),
                                   max_seqs=ecfg.max_seqs,
                                   prefix_tails=ecfg.prefix_cache and conv,
                                   kv_dtype=ecfg.kv_dtype)
        self.caches = PC.shard_pools(base, mesh, ns)
        # one swap store per shard: its byte cap and ``used`` accounting
        # pair with that shard's scheduler, and saves/restores slice the
        # stacked pools at the shard index
        self.swap_stores = [
            HostSwapStore(self, ecfg.swap_bytes, shard=s)
            if ecfg.swap_bytes > 0 else None for s in range(ns)]
        self.scheds = [Scheduler(
            num_pages=self.num_pages, page_size=self.page_size,
            max_seqs=ecfg.max_seqs, max_pages_per_seq=self.pages_per_seq,
            max_prefill_batch=ecfg.max_prefill_batch,
            chunk_tokens=ecfg.prefill_chunk,
            prefix_cache=ecfg.prefix_cache, key_conv=conv,
            full_page_match=ecfg.kv_dtype != "fp32",
            swap=self.swap_stores[s]) for s in range(ns)]
        self.router = Router(self.scheds)
        self._chunk_aware = bool(ecfg.prefill_chunk or ecfg.prefix_cache
                                 or ecfg.swap_bytes > 0)
        self._prefill = jax.jit(
            S.make_sharded_paged_prefill_step(
                cfg, mesh, backend=self.attn_backend,
                chunked=self._chunk_aware, route_map=self._route_map),
            donate_argnums=(2,))
        self._decode = jax.jit(
            S.make_sharded_paged_decode_step(cfg, mesh,
                                             backend=self.attn_backend,
                                             route_map=self._route_map),
            donate_argnums=(2,))
        self._cur_tok = np.zeros((ns, ecfg.max_seqs), np.int32)
        self._next_rid = 0
        self._t0 = None
        self.finished: List[Request] = []
        # dispatch-ahead pipeline (mirrors Engine's): entries are
        # (per_shard request lists, the step's (ns, max_seqs) token
        # array still on device)
        self._inflight: Deque[Tuple[List[List[Request]], jax.Array]] = \
            collections.deque()
        self._tok_dev = None
        self._emitted: List[Tuple[Request, int]] = []
        for sch in self.scheds:
            sch.before_preempt = self._sync_for_preempt
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "preemptions": 0,
                      "cp_requests": 0, "cp_tokens": 0, "cp_s": 0.0,
                      "tree_evictions": 0, "pages_in_use_peak": 0,
                      "dispatch_depth_peak": 0, "pipeline_drains": 0}
        for k in self.scheds[0].stats:
            self.stats[k] = 0
        self.shard_stats = [{"prefill_tokens": 0, "decode_tokens": 0,
                             "requests": 0} for _ in range(ns)]
        # jit-cache hygiene: every prefill width ever compiled (the
        # shard-invariance regression test asserts this stays one entry
        # per distinct global bucket, never one per shard)
        self.prefill_widths: set = set()
        # context-parallel fallback state (built lazily on first use)
        self._cp_queue: Deque[Request] = collections.deque()
        self._cp_mesh = None
        self._cp_prefill = None
        self._cp_decode = None

    # ------------------------------------------------------------- intake
    def make_request(self, prompt: Sequence[int], max_new_tokens: int,
                     arrival: float = 0.0, eos_id: Optional[int] = None
                     ) -> Request:
        """Build a request WITHOUT queueing it — the staged intake.
        Routing happens at :meth:`prefill`; over-long requests that only
        the context-parallel fallback can serve must go through
        :meth:`submit` + the legacy loop instead."""
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, arrival=arrival,
                      eos_id=eos_id)
        self._next_rid += 1
        return req

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, eos_id: Optional[int] = None
               ) -> Request:
        req = self.make_request(prompt, max_new_tokens, arrival, eos_id)
        shard = self.router.pick(req)
        if shard < 0:
            need = len(req.prompt) + max_new_tokens
            if need > self.cp_capacity:
                raise ServingError(
                    f"request {req.rid}: prompt+gen {need} tokens exceed "
                    f"even the context-parallel capacity "
                    f"{self.cp_capacity} ({self.n_shards} shards)")
            self._cp_queue.append(req)       # longer than one shard's pool
            return req
        req.shard = shard
        self.scheds[shard].submit(req)
        self.shard_stats[shard]["requests"] += 1
        return req

    # --------------------------------------------------------------- sizes
    @property
    def shard_capacity(self) -> int:
        """Tokens one shard's pool can hold."""
        return self.num_pages * self.page_size

    @property
    def cp_capacity(self) -> int:
        """Max context the context-parallel fallback can decode: the
        fleet-wide pool equivalent, dense-cached over all shards."""
        return self.n_shards * self.shard_capacity

    # -------------------------------------------------------------- steps
    def _run_prefill(self, per_shard: List[List[Request]]) -> None:
        """One shard_map prefill over every shard's batch.  All shards
        pad to ONE bucket derived from the global longest take via the
        pure :func:`prefill_bucket`, so the jit cache holds one prefill
        variant per bucket engine-wide instead of one per shard."""
        ns, bp = self.n_shards, self.ecfg.max_prefill_batch
        takes = [prefill_takes(reqs, self.ecfg.prefill_chunk)
                 for reqs in per_shard]
        gmax = max(max(t) for t in takes if t)
        lmax = prefill_bucket(gmax, self.page_size)
        self.prefill_widths.add(lmax)
        rows = [build_prefill_batch(self.scheds[s], per_shard[s], takes[s],
                                    bp, self.pages_per_seq, lmax)
                for s in range(ns)]
        # shard-invariant bucketing: every shard's rows must be padded to
        # the one global bucket — fires if a refactor reintroduces
        # per-shard local buckets (the jit-cache fragmentation bug)
        assert all(r[0].shape == (bp, lmax) for r in rows), \
            [r[0].shape for r in rows]
        tokens, kv_len, q_len, slots, active, table = (
            np.stack([r[i] for r in rows]) for i in range(6))
        t0 = time.perf_counter()
        tok, self.caches = self._prefill(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(table), jnp.asarray(kv_len), jnp.asarray(q_len),
            jnp.asarray(slots), jnp.asarray(active))
        tok = np.asarray(tok)
        self.stats["prefill_s"] += time.perf_counter() - t0
        wall = self._wall()
        for s in range(ns):
            n_tok = int(sum(takes[s]))
            self.stats["prefill_tokens"] += n_tok
            self.shard_stats[s]["prefill_tokens"] += n_tok
            record_prefill(per_shard[s], takes[s], tok[s],
                           self._cur_tok[s], wall)

    def _wall(self) -> float:
        return (0.0 if self._t0 is None
                else time.perf_counter() - self._t0)

    # ------------------------------------------- dispatch-ahead pipeline
    def _dispatch_decode(self, per_shard: List[List[Request]]) -> None:
        """Enqueue one shard_map decode step across ALL shards without
        blocking on its tokens (see ``Engine._dispatch_decode``)."""
        ns, ms = self.n_shards, self.ecfg.max_seqs
        rows = [build_decode_batch(reqs, ms) for reqs in per_shard]
        kv_len = np.stack([r[0] for r in rows])
        active = np.stack([r[1] for r in rows])
        table = np.stack([sch.block_table for sch in self.scheds])
        if self._tok_dev is None:
            self._tok_dev = jnp.asarray(self._cur_tok)
        t0 = time.perf_counter()
        tok, self.caches = self._decode(
            self.params, self._tok_dev, self.caches,
            jnp.asarray(table), jnp.asarray(kv_len), jnp.asarray(active))
        self._tok_dev = jnp.where(jnp.asarray(active), tok, self._tok_dev)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        for reqs in per_shard:
            for r in reqs:
                r.dispatched += 1
        self._inflight.append(([list(reqs) for reqs in per_shard], tok))
        self.stats["dispatch_depth_peak"] = max(
            self.stats["dispatch_depth_peak"], len(self._inflight))

    def _observe_one(self) -> None:
        per_shard, tok_dev = self._inflight.popleft()
        t0 = time.perf_counter()
        tok = np.asarray(tok_dev)
        self.stats["decode_s"] += time.perf_counter() - t0
        for s, reqs in enumerate(per_shard):
            for r in reqs:
                r.dispatched -= 1
                if r.state != "running" or r.done:
                    continue        # EOS overrun under dispatch-ahead
                r.cache_len += 1
                t = int(tok[s][r.slot])
                r.out.append(t)
                self._cur_tok[s, r.slot] = t
                self.stats["decode_tokens"] += 1
                self.shard_stats[s]["decode_tokens"] += 1
                if r.t_first is None:
                    r.t_first = self._wall()
                if self.ecfg.prefix_cache \
                        and r.cache_len % self.page_size == 0:
                    self.scheds[s].note_cached(r)
                self._emitted.append((r, t))
        if not self._inflight:
            self._tok_dev = None    # host vector authoritative again

    def drain(self) -> None:
        if self._inflight:
            self.stats["pipeline_drains"] += 1
        while self._inflight:
            self._observe_one()

    def _sync_for_preempt(self) -> None:
        self.drain()
        self._finish_done()

    def _finish_done(self) -> None:
        for sch in self.scheds:
            for r in [r for r in sch.running
                      if r.state == "running" and r.done
                      and r.dispatched == 0]:
                sch.finish(r)
                r.t_done = self._wall()
                self.finished.append(r)

    def _update_stats(self) -> None:
        for key in self.scheds[0].stats:
            self.stats[key] = sum(sch.stats[key] for sch in self.scheds)
        self.stats["tree_evictions"] = sum(
            sch.tree.evictions for sch in self.scheds
            if sch.tree is not None)
        self.stats["pages_in_use_peak"] = max(
            self.stats["pages_in_use_peak"],
            sum(self.num_pages - sch.alloc.available
                for sch in self.scheds))

    # ------------------------------------------------------------- stages
    def prefill(self, req: Request, now: float = float("inf")
                ) -> Optional[Prefix]:
        """Stage 1 over shard boundaries: route ``req`` (preemption
        replays keep their original shard — its swap store and prefix
        tree hold their state), admit it on that shard's scheduler, and
        cache + sample exactly as the single-shard engine.  Returns None
        when the shard cannot host it right now.  Raises for requests
        only the context-parallel fallback could serve: CP decode is
        synchronous and solo, so it is not staged — use :meth:`submit` +
        :meth:`run` for those."""
        if req.state not in ("waiting",) or req.slot >= 0:
            raise ServingError(
                f"request {req.rid}: prefill() on state {req.state!r} "
                f"(slot {req.slot}); only waiting requests stage")
        if self._t0 is None:
            self._t0 = time.perf_counter()
        fresh = req.shard < 0
        shard = self.router.pick(req) if fresh else req.shard
        if shard < 0:
            raise ServingError(
                f"request {req.rid}: no shard can page "
                f"{len(req.prompt)} + {req.max_new_tokens} tokens; the "
                f"context-parallel fallback is not staged — submit() + "
                f"run() serve it synchronously")
        sch = self.scheds[shard]
        queued = req in sch.waiting         # preemption replay
        if queued:
            sch.waiting.remove(req)
        ok = sch.admit(req)
        if not ok:
            self._sync_for_preempt()
            ok = sch.admit(req)
        if not ok:
            if queued:
                sch.waiting.appendleft(req)
            return None
        req.shard = shard
        if fresh:
            self.shard_stats[shard]["requests"] += 1
        # snapshot: the final chunk's record_prefill grows ``context``
        # by the sampled token (see Engine.prefill)
        target = len(req.context)
        first = True
        while req.cache_len < target:
            if not first:
                ok = sch._cow_tail(req)
                assert ok, "chunk continuation pages reserved at admission"
            self.caches = drain_cache_ops(self.caches, sch,
                                          self.swap_stores[shard],
                                          self.page_size, shard=shard)
            per = [[] for _ in range(self.n_shards)]
            per[shard] = [req]
            self._run_prefill(per)
            sch.note_cached(req)
            first = False
        req.state = "prefilled"
        self._update_stats()
        return Prefix(req=req, token=int(req.out[-1]), slot=req.slot,
                      shard=shard)

    def insert(self, prefix: Prefix, slot: Optional[int] = None) -> bool:
        """Stage 2: bind a prefilled request into its shard's decode
        batch.  False when the handle went stale (preempted since
        prefill) — re-prefill it."""
        req = prefix.req
        if slot is not None and slot != req.slot:
            raise ServingError(
                f"request {req.rid}: insert at slot {slot} but its pages "
                f"live at slot {req.slot} on shard {req.shard}; slots "
                f"bind at prefill")
        if req.state != "prefilled":
            return False
        req.state = "running"
        tok = int(req.out[-1])
        self._cur_tok[req.shard, req.slot] = tok
        if self._tok_dev is not None:
            self._tok_dev = self._tok_dev.at[req.shard, req.slot].set(tok)
        return True

    def generate_step(self, now: float = float("inf")
                      ) -> List[Tuple[Request, int]]:
        """Stage 3: per-shard growth/preemption plans, ONE shard_map
        decode dispatch across all shards, and the ``(request, token)``
        pairs observed this call (one pipeline-depth behind dispatch
        when ``dispatch_ahead > 0``)."""
        preempted = 0
        for sch in self.scheds:
            preempted += len(sch.plan_decode(now))
        self.stats["preemptions"] += preempted
        for s, sch in enumerate(self.scheds):
            self.caches = drain_cache_ops(self.caches, sch,
                                          self.swap_stores[s],
                                          self.page_size, shard=s)
        decodes = [[r for r in sch.running
                    if r.state == "running" and not r.budget_spent]
                   for sch in self.scheds]
        if any(decodes):
            self._dispatch_decode(decodes)
        depth = self.ecfg.dispatch_ahead if any(decodes) else 0
        while len(self._inflight) > depth:
            self._observe_one()
        self._finish_done()
        self._update_stats()
        out, self._emitted = self._emitted, []
        return out

    @property
    def preempted_waiting(self) -> List[Request]:
        """Preemption victims awaiting re-prefill, across all shards."""
        return [r for sch in self.scheds for r in sch.waiting
                if r.n_preempt > 0]

    # ------------------------------------------------- legacy closed loop
    def step(self, now: float = float("inf")) -> Dict:
        """One fleet iteration of the legacy driver, now layered on the
        stages: at most one arrived context-parallel request (served
        solo and synchronously), then per-shard admission plans and at
        most one shard_map prefill + one shard_map decode across shards,
        observed synchronously."""
        self.drain()
        n_cp = 0
        if self._cp_queue and self._cp_queue[0].arrival <= now:
            self._run_cp(self._cp_queue.popleft())
            n_cp = 1
        n_pre = 0
        for sch in self.scheds:
            n_pre += len(sch.plan_decode(now))
        self.stats["preemptions"] += n_pre
        prefills = [sch.plan_prefills(now) for sch in self.scheds]
        for s, sch in enumerate(self.scheds):
            self.caches = drain_cache_ops(self.caches, sch,
                                          self.swap_stores[s],
                                          self.page_size, shard=s)
        if any(prefills):
            self._run_prefill(prefills)
            for s, sch in enumerate(self.scheds):
                for r in prefills[s]:
                    sch.note_cached(r)
        decodes = [[r for r in sch.running
                    if r.state == "running" and not r.budget_spent]
                   for sch in self.scheds]
        if any(decodes):
            self._dispatch_decode(decodes)
            self.drain()
        n0 = len(self.finished)
        self._finish_done()
        n_done = len(self.finished) - n0
        self._emitted.clear()
        self._update_stats()
        return {"prefilled": sum(len(p) for p in prefills),
                "decoded": sum(len(d) for d in decodes),
                "finished": n_done + n_cp, "cp_served": n_cp,
                "preempted": n_pre}

    # ------------------------------------------- context-parallel fallback
    def _cp_setup(self):
        """Lazily build the CP mesh (same devices, ``model`` axis for
        `moba_decode_cp`'s collectives) and the dense-cache step pair on
        the ``sp`` backend.  ShardingConfig turns every other constraint
        off: only the MoBA KV cache is sequence-sharded."""
        if self._cp_mesh is None:
            self._cp_mesh = shmod.make_compat_mesh(
                (1, self.n_shards), ("data", "model"))
            self._cp_prefill = jax.jit(
                S.make_prefill_step(self.cfg, backend="sp"),
                donate_argnums=(2,))
            self._cp_decode = jax.jit(
                S.make_decode_step(self.cfg, backend="sp"),
                donate_argnums=(2,))
        return self._cp_mesh

    def _run_cp(self, req: Request) -> None:
        """Serve one over-long request with context-parallel decode: the
        dense KV cache (and its incremental centroid cache) is sharded
        over the mesh on the sequence dim inside `moba_decode_cp`'s
        shard_map; routing happens on shard-local centroids and only
        centroid scores cross chips (DESIGN.md §7)."""
        cfg = self.cfg
        mesh = self._cp_setup()
        # cache length: a multiple of shards × block size so every shard
        # holds whole blocks (moba_decode_cp's layout requirement)
        unit = self.n_shards * self.page_size
        need = len(req.prompt) + req.max_new_tokens
        max_len = -(-need // unit) * unit
        caches = T.init_caches(cfg, 1, max_len, dtype=jnp.dtype(cfg.dtype))
        scfg = ShardingConfig(fsdp=False, tensor_parallel=False,
                              sequence_parallel=False)
        t0 = time.perf_counter()
        with shmod.use_mesh(mesh, scfg):
            logits, caches = self._cp_prefill(
                self.params, jnp.asarray(req.prompt[None]), caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
                jnp.int32)
            req.cache_len = len(req.prompt)
            req.out.append(int(np.asarray(tok)[0, 0]))
            req.t_first = self._wall()
            while not req.done:
                tok, caches = self._cp_decode(self.params, tok, caches)
                req.out.append(int(np.asarray(tok)[0, 0]))
                req.cache_len += 1
        # CP wall time is tracked apart from the paged counters so
        # per-shard tokens/s (decode_tokens / decode_s) stays honest
        self.stats["cp_s"] += time.perf_counter() - t0
        self.stats["cp_requests"] += 1
        self.stats["cp_tokens"] += len(req.out)
        req.state = "done"
        req.t_done = self._wall()
        self.finished.append(req)

    # ---------------------------------------------------------------- run
    def has_work(self) -> bool:
        return (any(sch.has_work() for sch in self.scheds)
                or bool(self._cp_queue) or bool(self._inflight))

    def run(self, realtime: bool = False) -> List[Request]:
        """Drain all submitted requests (paged shards + CP fallback, in
        arrival order within each path) and return the ones finished by
        this call."""
        n0 = len(self.finished)
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while self.has_work():
            now = self._wall() if realtime else float("inf")
            self.step(now=now)
            if realtime and not any(sch.running for sch in self.scheds):
                pending = [sch.waiting[0].arrival for sch in self.scheds
                           if sch.waiting]
                pending += [r.arrival for r in list(self._cp_queue)[:1]]
                if pending:
                    wait = min(pending) - self._wall()
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
        return self.finished[n0:]
