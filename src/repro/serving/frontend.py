"""Front ends over the staged engine API (DESIGN.md §9).

Two drivers on top of ``prefill`` / ``insert`` / ``generate_step``:

  * :func:`run_open_loop` — a deterministic open-loop trace driver:
    requests arrive on a fixed schedule measured in DECODE STEPS
    (machine-independent, unlike wall-clock Poisson arrivals) whether or
    not the engine keeps up — the load model behind the sustained
    tokens/s and p99 TTFT numbers in ``BENCH_serve.json``.
  * :class:`AsyncFrontend` — a stdlib-``asyncio`` streaming front end:
    callers ``submit`` and consume per-request token streams while one
    pump task drives the stages; jitted device work runs in the default
    executor so the event loop stays responsive.  ``launch/serve.py
    --http`` wraps it in an HTTP server.

Both drivers handle preemption replay explicitly (victims surface on
``engine.preempted_waiting`` and are re-prefilled before new arrivals)
and work identically over ``Engine`` and ``ShardedEngine`` — the staged
protocol is the same; only the context-parallel fallback is excluded
(it is synchronous and solo, ``submit`` + ``run`` territory).
"""
from __future__ import annotations

import asyncio
import collections
import time
from dataclasses import dataclass
from typing import AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import Request


@dataclass(frozen=True)
class TraceItem:
    """One open-loop arrival: ``prompt`` lands ``arrival_step`` decode
    steps into the run, ready or not (that is what makes the trace open
    loop — the schedule never waits for the engine)."""
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_step: int = 0
    eos_id: Optional[int] = None


def run_open_loop(engine, trace: Sequence[TraceItem],
                  max_stalls: int = 3) -> List[Request]:
    """Drive ``engine`` through ``trace`` with the staged API and return
    the requests in trace order.

    Each iteration re-prefills preemption victims first (they hold
    replay priority), then admits every due arrival, then takes one
    :meth:`generate_step` — so admission happens at decode cadence, and
    a full pool simply defers arrivals to a later step (their ``arrival``
    timestamp is stamped when due, so TTFT charges the queueing delay).
    Raises ``RuntimeError`` if the engine stalls with arrivals that can
    never be admitted.
    """
    pending = collections.deque(
        sorted(enumerate(trace), key=lambda p: (p[1].arrival_step, p[0])))
    reqs: List[Optional[Request]] = [None] * len(trace)
    step = 0
    stalls = 0
    while pending or engine.has_work():
        for r in list(engine.preempted_waiting):
            p = engine.prefill(r)
            if p is None:
                break
            engine.insert(p)
        admitted = False
        while pending and pending[0][1].arrival_step <= step:
            i, item = pending[0]
            if reqs[i] is None:
                reqs[i] = engine.make_request(
                    item.prompt, item.max_new_tokens, eos_id=item.eos_id)
                reqs[i].arrival = engine._wall()   # due now: TTFT clock
            p = engine.prefill(reqs[i])            # starts, queued or not
            if p is None:
                break                              # pool full: next step
            engine.insert(p)
            pending.popleft()
            admitted = True
        emitted = engine.generate_step()
        step += 1
        if emitted or admitted or engine.has_work():
            stalls = 0
        elif pending:
            step = max(step, pending[0][1].arrival_step)   # idle gap
            stalls += 1
            if stalls > max_stalls:
                raise RuntimeError(
                    f"open-loop driver stalled: {len(pending)} arrivals "
                    f"cannot be admitted on an idle engine")
    return [r for r in reqs if r is not None]


def open_loop_metrics(reqs: Sequence[Request], wall_s: float,
                      stats: Dict) -> Dict:
    """Latency/throughput accounting for a finished open-loop run:
    sustained tokens/s over the whole wall, TTFT (arrival → first
    token, queueing included) and TPOT (steady-state inter-token time)
    percentiles, plus the pipeline-depth evidence that dispatch-ahead
    actually engaged."""
    ttft = np.array([r.t_first - r.arrival for r in reqs]) \
        if reqs else np.zeros(1)
    tpot = np.array([(r.t_done - r.t_first) / (len(r.out) - 1)
                     for r in reqs if len(r.out) > 1])
    if tpot.size == 0:
        tpot = np.zeros(1)
    total = sum(len(r.out) for r in reqs)
    return {
        "requests": len(reqs),
        "wall_s": wall_s,
        "generated_tokens": total,
        "sustained_tokens_per_s": total / max(wall_s, 1e-9),
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "tpot_p50_ms": float(np.percentile(tpot, 50) * 1e3),
        "tpot_p99_ms": float(np.percentile(tpot, 99) * 1e3),
        "dispatch_depth_peak": stats["dispatch_depth_peak"],
        "pipeline_drains": stats["pipeline_drains"],
        "preemptions": stats["preemptions"],
        "decode_steps": stats["decode_steps"],
    }


class AsyncFrontend:
    """Async streaming front end over one staged engine.

    One pump task owns the engine; callers interact through
    :meth:`submit` (returns the request) and :meth:`stream` (async
    iterator of its tokens, closing when generation finishes).  Device
    work — prefill chunks and decode steps — runs in the event loop's
    default executor, so awaiting callers are only ever blocked by their
    own tokens' availability, not by the host thread.

    Usage::

        fe = AsyncFrontend(engine)
        await fe.start()
        req = fe.submit(prompt, max_new_tokens=32)
        async for tok in fe.stream(req):
            ...
        await fe.close()
    """

    def __init__(self, engine):
        self.engine = engine
        self._queues: Dict[int, asyncio.Queue] = {}
        self._pending: collections.deque = collections.deque()
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._fin_cursor = len(engine.finished)
        self._closed = False

    async def start(self) -> None:
        self._wake = asyncio.Event()
        self._task = asyncio.create_task(self._pump())

    async def close(self) -> None:
        """Stop the pump after in-flight requests finish; pending
        streams get their sentinel either way."""
        self._closed = True
        self._wake.set()
        if self._task is not None:
            await self._task

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        if self._closed:
            raise RuntimeError("frontend closed")
        req = self.engine.make_request(prompt, max_new_tokens,
                                       eos_id=eos_id)
        req.arrival = self.engine._wall()
        self._queues[req.rid] = asyncio.Queue()
        self._pending.append(req)
        if self._wake is not None:
            self._wake.set()
        return req

    async def stream(self, req: Request) -> AsyncIterator[int]:
        q = self._queues.get(req.rid)
        if q is None:
            raise KeyError(f"request {req.rid} unknown or already "
                           f"consumed")
        while True:
            tok = await q.get()
            if tok is None:
                # consumer owns cleanup: the pump only enqueues the
                # sentinel, so a stream opened after the request
                # finished still drains its tokens
                self._queues.pop(req.rid, None)
                return
            yield tok

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        eng = self.engine

        def _emit(rid: int, tok: Optional[int]) -> None:
            q = self._queues.get(rid)
            if q is not None:
                q.put_nowait(tok)

        while True:
            if not self._pending and not eng.has_work():
                if self._closed:
                    break
                self._wake.clear()
                await self._wake.wait()
                continue
            for r in list(eng.preempted_waiting):    # replay priority
                p = await loop.run_in_executor(None, eng.prefill, r)
                if p is None:
                    break
                eng.insert(p)
                _emit(r.rid, p.token)    # replays resample a NEW token
            while self._pending:
                req = self._pending[0]
                p = await loop.run_in_executor(None, eng.prefill, req)
                if p is None:
                    break                # pool full: retry next tick
                eng.insert(p)
                self._pending.popleft()
                _emit(req.rid, p.token)  # first token comes from prefill
            emitted = await loop.run_in_executor(None, eng.generate_step)
            for r, tok in emitted:
                _emit(r.rid, tok)
            for r in eng.finished[self._fin_cursor:]:
                _emit(r.rid, None)       # close the stream
            self._fin_cursor = len(eng.finished)
            await asyncio.sleep(0)       # let consumers drain
        for rid in list(self._queues):   # closed with work undone
            _emit(rid, None)


def time_open_loop(engine, trace: Sequence[TraceItem]) -> Dict:
    """Convenience wrapper: run the trace, return its metrics dict plus
    the finished requests under ``"_requests"`` (callers that only want
    JSON can ``pop`` it)."""
    t0 = time.perf_counter()
    reqs = run_open_loop(engine, trace)
    wall = time.perf_counter() - t0
    m = open_loop_metrics(reqs, wall, engine.stats)
    m["_requests"] = reqs
    return m
