"""Paged-KV serving subsystem: block-table caches, incremental centroid
caches, and a continuous-batching engine for MoBA decode.

Layering (DESIGN.md §4):
  * :mod:`repro.serving.paged_cache` — device-side page pools + pure
    scatter/gather/centroid-update functions (everything jittable).
  * :mod:`repro.serving.scheduler` — host-side request lifecycle: page
    allocator, admit / finish / preempt, prefill batching decisions.
  * :mod:`repro.serving.engine` — glues the two: owns the jitted step
    functions and the device cache state, drains a request stream.
"""
__all__ = ["Engine", "EngineConfig", "Request", "Scheduler"]


def __getattr__(name):  # lazy: models.layers imports paged_cache at call
    # time; pulling the engine in eagerly would cycle back into models.
    if name in ("Engine", "EngineConfig"):
        from repro.serving import engine
        return getattr(engine, name)
    if name in ("Request", "Scheduler"):
        from repro.serving import scheduler
        return getattr(scheduler, name)
    raise AttributeError(name)
