"""Paged-KV serving subsystem: block-table caches, incremental centroid
caches, and a continuous-batching engine for MoBA decode.

Layering (DESIGN.md §4):
  * :mod:`repro.serving.paged_cache` — device-side page pools + pure
    scatter/gather/centroid-update functions (everything jittable).
  * :mod:`repro.serving.scheduler` — host-side request lifecycle: page
    allocator, admit / finish / preempt, prefill batching decisions.
  * :mod:`repro.serving.engine` — glues the two: owns the jitted step
    functions and the device cache state, drains a request stream.
  * :mod:`repro.serving.sharded` — the multi-host tier (DESIGN.md §7):
    per-shard pools over the mesh ``data`` axis, a least-loaded host
    router, shard_map step functions, and a context-parallel fallback
    for requests longer than one shard's pool.
  * :mod:`repro.serving.frontend` — drivers over the staged API
    (``prefill`` / ``insert`` / ``generate_step``): the deterministic
    open-loop trace harness and the asyncio streaming front end.
"""
__all__ = ["AsyncFrontend", "Engine", "EngineConfig", "Prefix",
           "Request", "Router", "Scheduler", "ShardedEngine",
           "TraceItem", "run_open_loop"]


def __getattr__(name):  # lazy: models.layers imports paged_cache at call
    # time; pulling the engine in eagerly would cycle back into models.
    if name in ("Engine", "EngineConfig", "Prefix"):
        from repro.serving import engine
        return getattr(engine, name)
    if name in ("AsyncFrontend", "TraceItem", "run_open_loop"):
        from repro.serving import frontend
        return getattr(frontend, name)
    if name in ("Router", "ShardedEngine"):
        from repro.serving import sharded
        return getattr(sharded, name)
    if name in ("Request", "Scheduler"):
        from repro.serving import scheduler
        return getattr(scheduler, name)
    raise AttributeError(name)
