"""Host-side continuous-batching scheduler: request lifecycle + pages.

Pure bookkeeping — no jax.  The scheduler owns the refcounted page pool
and the authoritative block table (numpy); the engine snapshots the
table into device arrays each step.  Policies are deliberately simple
and documented:

  * admission: FIFO by arrival; a request is admitted when a sequence
    slot is free and the pool can cover its whole context plus one decode
    token.  Admission happens every step — new requests join the running
    batch without draining it (continuous batching).  With the prefix
    cache enabled, admission first matches the longest cached prefix in
    the radix tree (``serving/prefix_tree.py``) and maps those logical
    blocks onto the existing physical pages (refcount++; their cached
    centroids come for free) so only the suffix is prefilled; a
    partially-matched tail page is copy-on-write'd to a fresh page
    before the suffix writes into it.
  * growth: before each decode step every running sequence is guaranteed
    a slot for one more token; crossing a page boundary allocates a page
    (evicting cold unreferenced tree prefixes under pressure).
  * preemption: when the pool is exhausted the *youngest* running request
    is evicted.  With a host swap store its written pages (and key-conv
    ring row) are snapshotted to host memory and restored on
    re-admission; without one — or when the store is over its byte cap —
    its full context is requeued for recompute-prefill, which with
    greedy decoding reproduces the interrupted stream exactly (and with
    the prefix cache, the recompute itself hits the victim's own pages
    still referenced by the tree).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.prefix_tree import PrefixTree


class ServingError(ValueError):
    """User-facing configuration error (unsupported arch, impossible
    sizing) — distinguishable from genuine internal ValueErrors so CLI
    entry points can report it cleanly without eating tracebacks."""


class UnsupportedFeatureError(ServingError):
    """A config/request needs a feature this engine build lacks (key-conv
    caches, an attention backend without paged support, a non-attention
    layer pattern).  Raised at admission time — engine construction or
    request submit — so a bad request fails fast with a structured
    (feature, reason) instead of crashing inside a jitted step."""

    def __init__(self, feature: str, reason: str):
        self.feature = feature
        self.reason = reason
        super().__init__(f"unsupported feature {feature!r}: {reason}")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # int32 (L,) original prompt
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None
    # runtime state
    out: List[int] = dataclasses.field(default_factory=list)
    state: str = "waiting"              # waiting | prefill | prefilled |
    #                                     running | done
    #   "prefill": admitted under chunked prefill with context tokens
    #   still to cache; holds a slot and pages but does not decode yet.
    #   "prefilled": staged-API holding state — context fully cached and
    #   first token sampled (engine.prefill), awaiting engine.insert;
    #   holds its slot and pages but does not decode yet.
    slot: int = -1
    shard: int = -1                     # owning shard (sharded engine);
    #   -1 = single-host or context-parallel fallback
    cache_len: int = 0                  # tokens whose KV is in the cache
    #   and *observed* by the host; dispatch-ahead decode steps that are
    #   still in flight have written further — see ``dispatched``
    dispatched: int = 0                 # decode steps dispatched to the
    #   device but not yet observed (dispatch-ahead pipelining).  Each
    #   wrote one KV position past ``cache_len``; observation moves it
    #   into ``cache_len``/``out``.  Always 0 between synchronous steps.
    n_preempt: int = 0
    prefix_len: int = 0                 # tokens served from the prefix
    #   cache at the most recent admission (0 = no hit / cache off)
    swap_data: Optional[dict] = None    # host snapshot of a preempted
    #   sequence's pages/ring (engine.HostSwapStore), or None
    t_first: Optional[float] = None     # first-token wall time
    t_done: Optional[float] = None

    @property
    def context(self) -> np.ndarray:
        """Prompt plus generated-so-far: what a recompute-prefill feeds.
        The last generated token is included — prefilling it emits the
        *next* token, exactly where the evicted decode left off."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.out
                and self.out[-1] == self.eos_id)

    @property
    def budget_spent(self) -> bool:
        """Generation budget exhausted *counting in-flight steps*: a
        request whose observed tokens plus dispatched-ahead decode steps
        cover ``max_new_tokens`` (or that already hit EOS) must not
        enter another decode batch — the pipeline would overrun its
        reserved pages.  Equals :attr:`done` when nothing is in flight,
        so the synchronous driver is unchanged."""
        return (self.done
                or len(self.out) + self.dispatched >= self.max_new_tokens)


class PagePool:
    """Refcounted free-list allocator over ``num_pages`` physical pages.

    A page's refcount is the number of logical mappings onto it: one per
    sequence whose block table points at it, plus one if the prefix tree
    references it, plus a transient pin while a scheduled
    copy-on-write reads from it.  ``alloc`` hands out a page at
    refcount 1; ``deref`` returns it to the free list when the count
    hits zero.  Double-frees and out-of-range ids raise a shaped
    :class:`ServingError` instead of silently corrupting the free list.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))
        self._ref = np.zeros((num_pages,), np.int32)

    @property
    def available(self) -> int:
        return len(self._free)

    def _check(self, page) -> int:
        if not isinstance(page, (int, np.integer)) \
                or not 0 <= page < self.num_pages:
            raise ServingError(
                f"page id {page!r} out of range [0, {self.num_pages})")
        return int(page)

    def refcount(self, page: int) -> int:
        return int(self._ref[self._check(page)])

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        page = self._free.pop()
        self._ref[page] = 1
        return page

    def ref(self, page: int) -> None:
        page = self._check(page)
        if self._ref[page] <= 0:
            raise ServingError(
                f"page {page}: ref() on a free page (refcount 0)")
        self._ref[page] += 1

    def deref(self, page: int) -> bool:
        """Drop one reference; True when this freed the page."""
        page = self._check(page)
        if self._ref[page] <= 0:
            raise ServingError(
                f"page {page}: double free (refcount already 0)")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)
            return True
        return False

    def release(self, pages: List[int]) -> None:
        """Deref every page in ``pages`` (a sequence's mapping list).
        Shared pages survive under their remaining references; a page
        id repeated beyond its refcount raises the double-free error."""
        for page in pages:
            self.deref(page)


# legacy name: pre-virtualization callers constructed the allocator
# directly; the refcounted pool is a drop-in superset
PageAllocator = PagePool


@dataclasses.dataclass
class StepPlan:
    prefills: List[Request]
    # requests already in the decode phase at *plan* time.  The engine
    # recomputes the authoritative decode batch after running prefills,
    # because requests whose final chunk (or one-shot prefill) lands this
    # step join decoding in the same iteration.
    decodes: List[Request]
    preempted: List[Request]


class Scheduler:
    def __init__(self, *, num_pages: int, page_size: int, max_seqs: int,
                 max_pages_per_seq: int, max_prefill_batch: int = 4,
                 chunk_tokens: int = 0, prefix_cache: bool = False,
                 key_conv: bool = False, full_page_match: bool = False,
                 swap=None):
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.max_pages_per_seq = max_pages_per_seq
        self.max_prefill_batch = max_prefill_batch
        # chunked prefill: admit long prompts in fixed-token chunks spread
        # over engine steps (0 = whole-prompt prefill).  Pages for the
        # full context are still reserved at admission, so chunking
        # bounds per-step prefill *compute*, not memory — no new
        # deadlock conditions.
        self.chunk_tokens = chunk_tokens
        # key-conv configs restore ring-buffer state from per-page raw-key
        # tails, which only exist for fully written pages — their prefix
        # matches are rounded down to whole pages (full_only).  Quantized
        # pools (``full_page_match``) share the constraint for a
        # different reason: writing a suffix into a COW'd partial page
        # requantizes its shared tokens against a new scale, so only
        # fully written pages are bit-exact to share.
        self.key_conv = key_conv
        self.full_page_match = key_conv or full_page_match
        self.tree = PrefixTree(page_size) if prefix_cache else None
        self.swap = swap                # engine.HostSwapStore or None
        self.alloc = PagePool(num_pages)
        self.block_table = np.full((max_seqs, max_pages_per_seq), -1,
                                   np.int32)
        self._seq_pages: List[List[int]] = [[] for _ in range(max_seqs)]
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        self.waiting: Deque[Request] = collections.deque()
        self.running: List[Request] = []    # admission order (oldest first)
        # device-side cache ops this plan scheduled; the engine drains
        # them (take_cache_ops) and applies them before the step's first
        # prefill/decode write
        self._cache_ops: Dict[str, list] = {
            "copies": [], "restores": [], "ring_loads": []}
        self.stats = {"prefix_queries": 0, "prefix_hits": 0,
                      "prefix_hit_tokens": 0, "prefix_prompt_tokens": 0,
                      "cow_copies": 0, "swap_saves": 0,
                      "swap_restores": 0, "swap_fallbacks": 0}
        # dispatch-ahead hook: called once per plan before the first
        # preemption (and before the victim's pages are snapshotted), so
        # the engine can observe in-flight decode steps and retire
        # finished requests first — preemption then always sees
        # host-consistent state and may even become unnecessary
        self.before_preempt = None

    # ------------------------------------------------------------- intake
    def validate(self, req: Request) -> None:
        """Raise a shaped error when ``req`` can never be served by this
        scheduler's pool, no matter how empty it gets."""
        need = len(req.prompt) + req.max_new_tokens
        cap = self.max_pages_per_seq * self.page_size
        if need > cap:
            raise ServingError(
                f"request {req.rid}: prompt+gen {need} tokens "
                f"exceed per-sequence capacity {cap}")
        if self._pages_for(need) > self.alloc.num_pages:
            raise ServingError(
                f"request {req.rid} can never fit: needs "
                f"{self._pages_for(need)} pages, pool has "
                f"{self.alloc.num_pages}")

    def submit(self, req: Request) -> None:
        self.validate(req)
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------- router metrics
    @property
    def committed_pages(self) -> int:
        """Pages currently mapped by running/prefilling sequences (shared
        pages count once per mapping — each mapping is real demand the
        sequence would otherwise allocate).  Tree-only pages are
        excluded: they are reclaimable, not load."""
        return sum(len(p) for p in self._seq_pages)

    @property
    def queued_pages(self) -> int:
        """Pages the waiting queue will need (whole context + 1 token
        each — the same reservation admission makes)."""
        return sum(self._pages_for(len(r.context) + 1)
                   for r in self.waiting)

    @property
    def load(self) -> int:
        """Router load metric: committed + queued page demand.  A pure
        function of scheduler state so least-loaded routing is
        deterministic for a given submission order."""
        return self.committed_pages + self.queued_pages

    def fits(self, req: Request) -> bool:
        """Whether this shard can ever serve ``req`` (same conditions
        ``submit`` enforces, as a predicate instead of a raise)."""
        need = len(req.prompt) + req.max_new_tokens
        return (need <= self.max_pages_per_seq * self.page_size
                and self._pages_for(need) <= self.alloc.num_pages)

    def peek_prefix(self, req: Request) -> int:
        """Tokens of ``req``'s context the prefix cache could serve,
        without touching LRU clocks or taking refs — the sharded
        router's shard-affinity signal."""
        if self.tree is None:
            return 0
        return self.tree.match_len(req.context,
                                   max_tokens=self._match_cap(req),
                                   full_only=self.full_page_match)

    # ------------------------------------------------------------ helpers
    def _pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def _match_cap(self, req: Request) -> int:
        """At least one context token must always be prefilled (its
        logits emit the next token), and key-conv / quantized-pool
        matches stop at whole pages (ring state restores from page-end
        tails; partial-page sharing would requantize shared tokens)."""
        cap = len(req.context) - 1
        if self.full_page_match:
            cap -= cap % self.page_size
        return cap

    def _alloc_page(self) -> Optional[int]:
        page = self.alloc.alloc()
        if page is None and self.tree is not None \
                and self.tree.evict(self.alloc, 1):
            page = self.alloc.alloc()
        return page

    def _grow_to(self, req: Request, n_tokens: int) -> bool:
        """Ensure req's block-table row covers ``n_tokens`` tokens."""
        pages = self._seq_pages[req.slot]
        while len(pages) < self._pages_for(n_tokens):
            page = self._alloc_page()
            if page is None:
                return False
            self.block_table[req.slot, len(pages)] = page
            pages.append(page)
        return True

    def _cow_tail(self, req: Request) -> bool:
        """Guarantee the page ``req`` writes next (its partially filled
        tail page) is exclusively owned, scheduling a device
        copy-on-write when it is shared.  False = pool exhausted (the
        caller preempts and retries).  Page-aligned positions always
        open a freshly allocated page, so only mid-page writes can hit a
        shared page."""
        # next write position counts dispatched-ahead steps still in
        # flight — they already wrote the positions past cache_len
        pos = req.cache_len + req.dispatched
        off = pos % self.page_size
        if off == 0:
            return True
        j = pos // self.page_size
        pages = self._seq_pages[req.slot]
        if j >= len(pages) or self.alloc.refcount(pages[j]) == 1:
            return True
        fresh = self._alloc_page()
        if fresh is None:
            return False
        # the sequence's own mapping ref on the shared source page
        # becomes the copy's pin — take_cache_ops derefs it at drain
        self._cache_ops["copies"].append((pages[j], fresh))
        self.stats["cow_copies"] += 1
        pages[j] = fresh
        self.block_table[req.slot, j] = fresh
        return True

    def _release(self, req: Request) -> None:
        slot = req.slot
        self.alloc.release(self._seq_pages[slot])
        self._seq_pages[slot] = []
        self.block_table[slot, :] = -1
        self._free_slots.append(slot)
        req.slot = -1

    def _preempt_youngest(self, spare: Request) -> Optional[Request]:
        """Evict the most recently admitted running request != spare.
        The victim's pages are swapped to the host store when one is
        attached and under its cap (restored at re-admission); otherwise
        its cached-so-far full pages are left to the prefix tree (when
        enabled) and the context requeued for recompute."""
        for victim in reversed(self.running):
            if victim is spare and len(self.running) > 1:
                continue
            # the before_preempt hook drained the pipeline, so the
            # victim's host state (cache_len, out) is authoritative —
            # an in-flight victim would lose unobserved tokens from its
            # swap snapshot and corrupt the observation bookkeeping
            assert victim.dispatched == 0, \
                f"preempting request {victim.rid} with " \
                f"{victim.dispatched} in-flight decode steps"
            self.running.remove(victim)
            saved = False
            if self.swap is not None and victim.cache_len > 0 \
                    and victim.slot >= 0:
                used = self._seq_pages[victim.slot][
                    :self._pages_for(victim.cache_len)]
                saved = self.swap.save(victim, used, victim.slot)
                self.stats["swap_saves" if saved
                           else "swap_fallbacks"] += 1
            if not saved:
                # recompute fallback: keep the victim's full pages
                # findable so its own re-prefill is a prefix hit
                self.note_cached(victim)
            self._release(victim)
            victim.state = "waiting"
            victim.cache_len = 0
            victim.n_preempt += 1
            self.waiting.appendleft(victim)
            return victim
        return None

    # ------------------------------------------------------- prefix cache
    def note_cached(self, req: Request, final: bool = False) -> None:
        """Register ``req``'s cached pages in the prefix tree so later
        requests can map them.  Mid-flight calls insert only fully
        written pages; ``final=True`` (at finish) additionally inserts
        the partial tail page.  No-op without the prefix cache."""
        if self.tree is None or req.slot < 0 or req.cache_len <= 0:
            return
        count = req.cache_len if final \
            else req.cache_len - req.cache_len % self.page_size
        if count <= 0:
            return
        pages = self._seq_pages[req.slot][:self._pages_for(count)]
        self.tree.insert(req.context[:count], pages, self.alloc)

    def take_cache_ops(self) -> Dict[str, list]:
        """Hand the engine this plan's device cache ops — COW page
        copies, swap restores, key-conv ring loads — to apply before the
        step's first write.  Copy sources were pinned when scheduled;
        their pins drop here (the freed ids cannot be reused before the
        engine executes the copies, because allocation only happens in
        the next ``plan_step``)."""
        ops = self._cache_ops
        self._cache_ops = {"copies": [], "restores": [], "ring_loads": []}
        for src, _ in ops["copies"]:
            self.alloc.deref(src)
        return ops

    # --------------------------------------------------------------- plan
    def admit(self, req: Request) -> bool:
        """Admission attempt: prefix-match, reserve pages for the whole
        context plus one decode token, map shared ones.  False =
        insufficient pages right now (the legacy planner's FIFO
        head-of-line blocks; the staged API retries after capacity
        frees).  The caller owns queue membership — ``req`` must NOT be
        on ``waiting`` (``plan_prefills`` pops it on success; the staged
        ``Engine.prefill`` admits arbitrary requests directly)."""
        if not self._free_slots:
            return False
        ctx = len(req.context)
        swapped = req.swap_data is not None
        matched_pages: List[int] = []
        matched = 0
        if self.tree is not None and not swapped:
            matched_pages, matched = self.tree.match(
                req.context, max_tokens=self._match_cap(req),
                full_only=self.full_page_match)
        n_full = matched // self.page_size
        full_pages = matched_pages[:n_full]
        partial_src = (matched_pages[n_full]
                       if matched % self.page_size else None)
        for p in full_pages:
            self.alloc.ref(p)
        need_fresh = self._pages_for(ctx + 1) - n_full
        short = need_fresh - self.alloc.available
        if short > 0 and self.tree is not None:
            self.tree.evict(self.alloc, short)
        if need_fresh > self.alloc.available:
            for p in full_pages:
                self.alloc.deref(p)
            return False
        req.slot = self._free_slots.pop()
        seq_pages = self._seq_pages[req.slot]
        for j, p in enumerate(full_pages):
            self.block_table[req.slot, j] = p
            seq_pages.append(p)
        if partial_src is not None:
            # eager copy-on-write: the tail page's content diverges past
            # ``matched``, and the suffix prefill writes into it this
            # very step — map a fresh copy, never the shared page
            fresh = self.alloc.alloc()
            self.alloc.ref(partial_src)          # pin until the copy runs
            self._cache_ops["copies"].append((partial_src, fresh))
            self.stats["cow_copies"] += 1
            self.block_table[req.slot, n_full] = fresh
            seq_pages.append(fresh)
        req.cache_len = matched
        req.prefix_len = matched
        if self.tree is not None and not swapped:
            self.stats["prefix_queries"] += 1
            self.stats["prefix_hits"] += int(matched > 0)
            self.stats["prefix_hit_tokens"] += matched
            self.stats["prefix_prompt_tokens"] += ctx
        if self.key_conv and matched:
            self._cache_ops["ring_loads"].append(
                (req.slot, full_pages[-1]))
        ok = self._grow_to(req, ctx + 1)
        assert ok, "admission checked page availability"
        if swapped:
            # engine restores pages + cache_len before this step's
            # prefill; the remaining suffix is exactly one token
            self._cache_ops["restores"].append(req)
            remaining = ctx - req.swap_data["n_tokens"]
        else:
            remaining = ctx - matched
        # chunked mode admits into the "prefill" phase; the engine
        # flips it to "running" once the final chunk is cached.
        req.state = ("prefill" if self.chunk_tokens
                     and remaining > self.chunk_tokens else "running")
        self.running.append(req)
        return True

    def plan_decode(self, now: float = float("inf")) -> List[Request]:
        """Growth half of the plan, callable at decode cadence without
        admitting anyone: every running sequence that will decode next
        step gets room for one more token — and exclusive ownership of
        the page it writes into (COW) — preempting from the back under
        pressure (oldest survives).  Requests whose generation budget is
        already covered by dispatched-ahead steps are skipped: they
        never decode again, so growing them would waste pages (and
        could preempt someone for nothing).  Returns the victims."""
        preempted: List[Request] = []
        drained = False
        for req in list(self.running):
            if req.state not in ("running", "prefill"):
                continue
            if req.state == "running" and req.budget_spent:
                continue
            while req.state in ("running", "prefill") and not (
                    self._cow_tail(req)
                    and (req.state != "running"
                         or self._grow_to(
                             req, req.cache_len + req.dispatched + 1))):
                if not drained and self.before_preempt is not None:
                    # observe the in-flight pipeline (retiring finished
                    # requests frees their pages) before evicting anyone
                    # — the retry below may then succeed without a
                    # victim, and any victim has nothing in flight
                    self.before_preempt()
                    drained = True
                    continue
                victim = self._preempt_youngest(spare=req)
                if victim is None or victim is req:
                    if victim is None:       # cannot happen: req holds pages
                        raise RuntimeError("page pool deadlock")
                    preempted.append(victim)
                    break
                preempted.append(victim)
        return preempted

    def plan_prefills(self, now: float = float("inf")) -> List[Request]:
        """Admission half of the plan, decoupled from decode cadence —
        the legacy ``step()`` calls it every iteration, the staged API
        not at all (``Engine.prefill`` admits directly)."""
        # 1. chunk continuation: admitted requests with context still to
        #    cache run their next chunk before any new admission (they
        #    already hold slots and pages); overflow waits a step.
        prefills: List[Request] = [r for r in self.running
                                   if r.state == "prefill"
                                   ][:self.max_prefill_batch]

        # 2. admission (FIFO, arrivals only): whole context + one decode
        #    token must fit (chunking spreads the *compute*, not the
        #    reservation); prefix hits map cached pages and reserve only
        #    the rest.
        while (self.waiting and self._free_slots
               and len(prefills) < self.max_prefill_batch
               and self.waiting[0].arrival <= now):
            req = self.waiting[0]
            if not self.admit(req):
                break                        # FIFO head-of-line blocking
            self.waiting.popleft()
            prefills.append(req)
        return prefills

    def plan_step(self, now: float = float("inf")) -> StepPlan:
        """Legacy one-shot plan: growth + admission in one call — kept
        as the compatibility surface over the decoupled halves."""
        preempted = self.plan_decode(now)
        prefills = self.plan_prefills(now)
        decodes = [r for r in self.running if r.state == "running"]
        return StepPlan(prefills=prefills, decodes=decodes,
                        preempted=preempted)

    # ------------------------------------------------------------- finish
    def finish(self, req: Request) -> None:
        """Retire a request.  Robust to requests that were preempted back
        to the waiting queue (no slot, no pages) — e.g. cancelled or
        finished-by-policy while waiting for re-admission."""
        if req.state == "done":
            return
        if req in self.running:
            self.running.remove(req)
            # leave the finished context findable: full pages plus the
            # partial tail survive under the tree's refs
            self.note_cached(req, final=True)
            self._release(req)
        else:
            try:
                self.waiting.remove(req)
            except ValueError:
                pass
        if self.swap is not None:
            self.swap.drop(req)
        req.state = "done"
