"""Host-side continuous-batching scheduler: request lifecycle + pages.

Pure bookkeeping — no jax.  The scheduler owns the free-page list and the
authoritative block table (numpy); the engine snapshots the table into
device arrays each step.  Policies are deliberately simple and documented:

  * admission: FIFO by arrival; a request is admitted when a sequence
    slot is free and the pool can cover its whole context plus one decode
    token.  Admission happens every step — new requests join the running
    batch without draining it (continuous batching).
  * growth: before each decode step every running sequence is guaranteed
    a slot for one more token; crossing a page boundary allocates a page.
  * preemption: when the pool is exhausted the *youngest* running request
    is evicted — its pages are freed and its full context (prompt plus
    everything generated so far) is requeued for recompute-prefill, which
    with greedy decoding reproduces the interrupted stream exactly.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, List, Optional

import numpy as np


class ServingError(ValueError):
    """User-facing configuration error (unsupported arch, impossible
    sizing) — distinguishable from genuine internal ValueErrors so CLI
    entry points can report it cleanly without eating tracebacks."""


class UnsupportedFeatureError(ServingError):
    """A config/request needs a feature this engine build lacks (key-conv
    caches, an attention backend without paged support, a non-attention
    layer pattern).  Raised at admission time — engine construction or
    request submit — so a bad request fails fast with a structured
    (feature, reason) instead of crashing inside a jitted step."""

    def __init__(self, feature: str, reason: str):
        self.feature = feature
        self.reason = reason
        super().__init__(f"unsupported feature {feature!r}: {reason}")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # int32 (L,) original prompt
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None
    # runtime state
    out: List[int] = dataclasses.field(default_factory=list)
    state: str = "waiting"              # waiting | prefill | running | done
    #   "prefill": admitted under chunked prefill with context tokens
    #   still to cache; holds a slot and pages but does not decode yet.
    slot: int = -1
    shard: int = -1                     # owning shard (sharded engine);
    #   -1 = single-host or context-parallel fallback
    cache_len: int = 0                  # tokens whose KV is in the cache
    n_preempt: int = 0
    t_first: Optional[float] = None     # first-token wall time
    t_done: Optional[float] = None

    @property
    def context(self) -> np.ndarray:
        """Prompt plus generated-so-far: what a recompute-prefill feeds.
        The last generated token is included — prefilling it emits the
        *next* token, exactly where the evicted decode left off."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.out
                and self.out[-1] == self.eos_id)


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, pages: List[int]) -> None:
        self._free.extend(pages)


@dataclasses.dataclass
class StepPlan:
    prefills: List[Request]
    # requests already in the decode phase at *plan* time.  The engine
    # recomputes the authoritative decode batch after running prefills,
    # because requests whose final chunk (or one-shot prefill) lands this
    # step join decoding in the same iteration.
    decodes: List[Request]
    preempted: List[Request]


class Scheduler:
    def __init__(self, *, num_pages: int, page_size: int, max_seqs: int,
                 max_pages_per_seq: int, max_prefill_batch: int = 4,
                 chunk_tokens: int = 0):
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.max_pages_per_seq = max_pages_per_seq
        self.max_prefill_batch = max_prefill_batch
        # chunked prefill: admit long prompts in fixed-token chunks spread
        # over engine steps (0 = whole-prompt prefill).  Pages for the
        # full context are still reserved at admission, so chunking
        # bounds per-step prefill *compute*, not memory — no new
        # deadlock conditions.
        self.chunk_tokens = chunk_tokens
        self.alloc = PageAllocator(num_pages)
        self.block_table = np.full((max_seqs, max_pages_per_seq), -1,
                                   np.int32)
        self._seq_pages: List[List[int]] = [[] for _ in range(max_seqs)]
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        self.waiting: Deque[Request] = collections.deque()
        self.running: List[Request] = []    # admission order (oldest first)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new_tokens
        cap = self.max_pages_per_seq * self.page_size
        if need > cap:
            raise ServingError(
                f"request {req.rid}: prompt+gen {need} tokens "
                f"exceed per-sequence capacity {cap}")
        if self._pages_for(need) > self.alloc.num_pages:
            raise ServingError(
                f"request {req.rid} can never fit: needs "
                f"{self._pages_for(need)} pages, pool has "
                f"{self.alloc.num_pages}")
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------- router metrics
    @property
    def committed_pages(self) -> int:
        """Pages currently held by running/prefilling sequences."""
        return self.alloc.num_pages - self.alloc.available

    @property
    def queued_pages(self) -> int:
        """Pages the waiting queue will need (whole context + 1 token
        each — the same reservation admission makes)."""
        return sum(self._pages_for(len(r.context) + 1)
                   for r in self.waiting)

    @property
    def load(self) -> int:
        """Router load metric: committed + queued page demand.  A pure
        function of scheduler state so least-loaded routing is
        deterministic for a given submission order."""
        return self.committed_pages + self.queued_pages

    def fits(self, req: Request) -> bool:
        """Whether this shard can ever serve ``req`` (same conditions
        ``submit`` enforces, as a predicate instead of a raise)."""
        need = len(req.prompt) + req.max_new_tokens
        return (need <= self.max_pages_per_seq * self.page_size
                and self._pages_for(need) <= self.alloc.num_pages)

    # ------------------------------------------------------------ helpers
    def _pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def _grow_to(self, req: Request, n_tokens: int) -> bool:
        """Ensure req's block-table row covers ``n_tokens`` tokens."""
        pages = self._seq_pages[req.slot]
        while len(pages) < self._pages_for(n_tokens):
            page = self.alloc.alloc()
            if page is None:
                return False
            self.block_table[req.slot, len(pages)] = page
            pages.append(page)
        return True

    def _release(self, req: Request) -> None:
        slot = req.slot
        self.alloc.release(self._seq_pages[slot])
        self._seq_pages[slot] = []
        self.block_table[slot, :] = -1
        self._free_slots.append(slot)
        req.slot = -1

    def _preempt_youngest(self, spare: Request) -> Optional[Request]:
        """Evict the most recently admitted running request != spare."""
        for victim in reversed(self.running):
            if victim is spare and len(self.running) > 1:
                continue
            self.running.remove(victim)
            self._release(victim)
            victim.state = "waiting"
            victim.cache_len = 0
            victim.n_preempt += 1
            self.waiting.appendleft(victim)
            return victim
        return None

    # --------------------------------------------------------------- plan
    def plan_step(self, now: float = float("inf")) -> StepPlan:
        preempted: List[Request] = []

        # 1. growth: every running sequence gets room for one more token,
        #    preempting from the back under pressure (oldest survives).
        for req in list(self.running):
            if req.state != "running":
                continue
            while not self._grow_to(req, req.cache_len + 1):
                victim = self._preempt_youngest(spare=req)
                if victim is None or victim is req:
                    if victim is None:       # cannot happen: req holds pages
                        raise RuntimeError("page pool deadlock")
                    preempted.append(victim)
                    break
                preempted.append(victim)
            if req.state != "running":       # req itself was the victim
                continue

        # 2. chunk continuation: admitted requests with context still to
        #    cache run their next chunk before any new admission (they
        #    already hold slots and pages); overflow waits a step.
        prefills: List[Request] = [r for r in self.running
                                   if r.state == "prefill"
                                   ][:self.max_prefill_batch]

        # 3. admission (FIFO, arrivals only): whole context + one decode
        #    token must fit (chunking spreads the *compute*, not the
        #    reservation).
        while (self.waiting and self._free_slots
               and len(prefills) < self.max_prefill_batch
               and self.waiting[0].arrival <= now):
            req = self.waiting[0]
            ctx = len(req.context)
            if self._pages_for(ctx + 1) > self.alloc.available:
                break                        # FIFO head-of-line blocking
            self.waiting.popleft()
            req.slot = self._free_slots.pop()
            # chunked mode admits into the "prefill" phase; the engine
            # flips it to "running" once the final chunk is cached.
            req.state = ("prefill" if self.chunk_tokens
                         and ctx > self.chunk_tokens else "running")
            req.cache_len = 0
            ok = self._grow_to(req, ctx + 1)
            assert ok, "admission checked page availability"
            self.running.append(req)
            prefills.append(req)

        decodes = [r for r in self.running if r.state == "running"]
        return StepPlan(prefills=prefills, decodes=decodes,
                        preempted=preempted)

    # ------------------------------------------------------------- finish
    def finish(self, req: Request) -> None:
        self.running.remove(req)
        self._release(req)
        req.state = "done"
