"""Continuous-batching serving engine over the paged KV cache.

The engine owns the device state (params + paged caches) and two jitted
step functions; the scheduler owns the host state (free pages, block
table, request queues).  The public surface is STAGED (DESIGN.md §9):

  * :meth:`Engine.prefill` — admit one request, cache its whole context
    (all chunks, applying any COW/swap/ring cache ops admission
    scheduled) and sample its first token; returns a :class:`Prefix`
    handle, or None when the pool cannot host it right now.
  * :meth:`Engine.insert` — bind a prefilled request into the decode
    batch at its slot.  Cheap and pipeline-safe: it only flips state
    and patches the device current-token vector.
  * :meth:`Engine.generate_step` — plan growth/preemption, dispatch one
    decode step over every bound slot, and return newly observed
    ``(request, token)`` pairs.  With ``dispatch_ahead > 0`` the host
    enqueues up to that many decode steps before blocking on the oldest
    one's tokens (JAX async dispatch keeps the device busy while the
    host plans); tokens then surface one pipeline-depth later.

The legacy closed loop — :meth:`step` / :meth:`run` — is reimplemented
on top of the stages as a thin synchronous driver (admission via
``Scheduler.plan_prefills``, drain every step), so both drive patterns
produce bit-identical greedy streams.

Shapes are kept jit-stable: the decode batch is always the full
``max_seqs`` slot array with an active mask, and prefill batches are
padded to ``max_prefill_batch`` rows with power-of-two token buckets, so
the engine compiles O(log max_seq_len) prefill variants and exactly one
decode variant.

Supported: attention-only layer patterns (dense / swa / moba /
shared_attn), dense and MoE families, key-conv (per-slot raw-key ring
buffers, DESIGN.md §4), and chunked prefill (DESIGN.md §6).  Recurrent
(ssm) and cross-attention archs fall back to the fixed-batch loop in
``launch/serve.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import backends as B
from repro.core import quantization as Q
from repro.launch import steps as S
from repro.models import transformer as T
from repro.serving import paged_cache as PC
from repro.serving.scheduler import (Request, Scheduler, ServingError,
                                     UnsupportedFeatureError)


def prefill_bucket(n: int, page_size: int) -> int:
    """Power-of-two token bucket for ragged prefill rows.

    Deliberately a pure function of ``(n, page_size)`` — independent of
    any engine or shard state — so every shard of a sharded engine pads
    its rows to the same width for the same longest take and the jit
    cache cannot fragment across shards (one compile per bucket,
    engine-wide).  ``tests/test_sharded_serving.py`` pins this."""
    b = max(16, page_size)
    while b < n:
        b *= 2
    return b


def resolve_engine_backend(spec: str, default: str) -> str:
    """``core.backends.resolve_backend_spec`` with admission-style
    errors: an unknown name or bad option string (e.g. ``flash:typo``)
    fails engine construction as a structured
    :class:`UnsupportedFeatureError`, like every other admission-time
    backend problem."""
    try:
        return B.resolve_backend_spec(spec, default=default)
    except B.BackendCapabilityError as e:
        raise UnsupportedFeatureError("attn_backend", str(e)) from e


def admission_capability_check(cfg: ModelConfig, backend: str,
                               sharded: bool = False,
                               kv_dtype: str = "fp32",
                               adaptive: bool = False) -> None:
    """Admission-time capability query shared by the single-host and
    sharded engines: every layer kind must resolve for both paged
    phases (with key-conv where the config carries it, mesh-free
    per-shard math when ``sharded``, quantized-pool support when
    ``kv_dtype`` is int8/fp8, and per-head ``head_top_k`` routing when
    ``adaptive``), or the request stream would die inside a jitted
    step."""
    a = cfg.attention
    conv = bool(a.moba is not None and a.moba.key_conv_width)
    kinds = {"dense" if k == "shared_attn" else k
             for k in cfg.layer_pattern}
    for kind in sorted(kinds):
        for phase in ("prefill", "decode"):
            try:
                B.resolve(backend, kind=kind, phase=phase, cache="paged",
                          key_conv=conv and kind == "moba",
                          sharded=sharded, kv_dtype=kv_dtype,
                          adaptive=adaptive and kind == "moba")
            except B.BackendCapabilityError as e:
                raise UnsupportedFeatureError("attn_backend",
                                              str(e)) from e


def build_route_profile(cfg: ModelConfig, params, route_policy: str,
                        pages_per_seq: int):
    """Resolve ``EngineConfig.route_policy`` into ``(profile,
    route_map)`` — ``(None, None)`` for static routing.

    ``snr:pfail=P`` runs the calibration pass (``core/adaptive.py``)
    against this engine's routing universe (``pages_per_seq``);
    ``profile:PATH`` loads a serialized artifact and validates it
    against the model's layer pattern and static ``top_k``, so routing
    decisions always come from the artifact, never recomputed state.
    Shared by the single-host and sharded engines (the sharded engine
    replicates one profile across shards)."""
    from repro.core import adaptive as AD

    try:
        mode, arg = AD.parse_route_policy(route_policy)
    except ValueError as e:
        raise UnsupportedFeatureError("route_policy", str(e)) from e
    if mode == "static":
        return None, None
    a = cfg.attention
    if a.moba is None or not any(k == "moba" for k in cfg.layer_pattern):
        raise UnsupportedFeatureError(
            "route_policy",
            f"adaptive routing needs a moba slot in the layer pattern; "
            f"got {cfg.layer_pattern}")
    if mode == "snr":
        profile = AD.calibrate_profile(cfg, params, arg,
                                       num_blocks=pages_per_seq)
    else:
        try:
            profile = AD.RoutingProfile.load(arg)
        except (OSError, ValueError, KeyError) as e:
            raise UnsupportedFeatureError(
                "route_policy", f"cannot load routing profile {arg!r}: "
                f"{e}") from e
    pattern = cfg.layer_pattern
    n_groups = cfg.num_layers // len(pattern)
    if profile.k_max != a.moba.top_k \
            or profile.block_size != a.moba.block_size:
        raise UnsupportedFeatureError(
            "route_policy",
            f"routing profile was calibrated for top_k={profile.k_max} "
            f"block_size={profile.block_size}, model has "
            f"top_k={a.moba.top_k} block_size={a.moba.block_size}")
    for slot, arr in profile.top_k.items():
        i = int(slot.rsplit("_", 1)[1])
        if i >= len(pattern) or pattern[i] != "moba" \
                or arr.shape != (n_groups, cfg.num_heads):
            raise UnsupportedFeatureError(
                "route_policy",
                f"routing profile slot {slot!r} (shape {arr.shape}) does "
                f"not match layer pattern {pattern} x {n_groups} groups "
                f"x {cfg.num_heads} heads")
    return profile, profile.route_map()


def resolve_pool_sizes(cfg: ModelConfig, ecfg: "EngineConfig"
                       ) -> Tuple[int, int, int]:
    """(page_size, pages_per_seq, num_pages) for one pool/shard."""
    page_size = ecfg.page_size or PC.resolve_page_size(cfg)
    pages_per_seq = math.ceil(ecfg.max_seq_len / page_size)
    num_pages = ecfg.num_pages or ecfg.max_seqs * pages_per_seq
    return page_size, pages_per_seq, num_pages


def prefill_takes(reqs: List[Request], chunk: int) -> List[int]:
    """Tokens each request contributes this step: the whole remaining
    context, or at most ``chunk`` of it under chunked prefill."""
    return [min(chunk, left) if chunk else left
            for left in (len(r.context) - r.cache_len for r in reqs)]


def build_prefill_batch(sched, reqs: List[Request], takes: List[int],
                        bp: int, pages_per_seq: int, lmax: int):
    """Host-side arrays for one ragged prefill batch (shared by the
    single-host and sharded engines).  Rows past ``len(reqs)`` are
    padding: q_len 0, slot −1, table −1, inactive."""
    tokens = np.zeros((bp, lmax), np.int32)
    kv_len = np.zeros((bp,), np.int32)
    q_len = np.zeros((bp,), np.int32)
    slots = np.full((bp,), -1, np.int32)
    active = np.zeros((bp,), bool)
    table = np.full((bp, pages_per_seq), -1, np.int32)
    for i, (r, take) in enumerate(zip(reqs, takes)):
        ctx = r.context
        tokens[i, :take] = ctx[r.cache_len:r.cache_len + take]
        kv_len[i] = r.cache_len
        q_len[i] = take
        slots[i] = r.slot
        active[i] = True
        table[i] = sched.block_table[r.slot]
    return tokens, kv_len, q_len, slots, active, table


def build_decode_batch(reqs: List[Request], max_seqs: int):
    """Per-slot (kv_len, active) arrays for one decode step.  ``kv_len``
    counts dispatched-ahead steps still in flight: they already wrote
    the positions past ``cache_len``, so the next step attends over (and
    writes after) them.  Zero in-flight reduces to the legacy batch."""
    kv_len = np.zeros((max_seqs,), np.int32)
    active = np.zeros((max_seqs,), bool)
    for r in reqs:
        kv_len[r.slot] = r.cache_len + r.dispatched
        active[r.slot] = True
    return kv_len, active


def record_prefill(reqs: List[Request], takes: List[int], tok: np.ndarray,
                   cur_tok: np.ndarray, wall: float) -> None:
    """Post-prefill request bookkeeping: advance chunk offsets; rows
    whose context completed this step record the sampled token and join
    decoding."""
    for i, (r, take) in enumerate(zip(reqs, takes)):
        r.cache_len += take
        if r.cache_len < len(r.context):
            continue                     # more chunks to come
        r.state = "running"              # final chunk: join decoding
        r.out.append(int(tok[i]))
        cur_tok[r.slot] = tok[i]
        if r.t_first is None:
            r.t_first = wall


def needs_key_conv(cfg: ModelConfig) -> bool:
    """Whether serving ``cfg`` exercises the key-conv ring buffers."""
    a = cfg.attention
    return bool(a.moba is not None and a.moba.key_conv_width
                and any(k == "moba" for k in cfg.layer_pattern))


class HostSwapStore:
    """Host-memory backing store for preempted sequences.

    ``save`` snapshots a victim's written pages (K/V, centroids, key-conv
    tails) plus its ring-buffer row into ``req.swap_data`` *before* the
    scheduler frees them; total residency is capped at
    ``capacity_bytes`` — an over-cap save returns False and the
    scheduler falls back to recompute preemption.  On re-admission the
    scheduler queues the request in its cache ops and the engine's
    :func:`drain_cache_ops` scatters the snapshot into the newly
    reserved pages, restores ``cache_len``, and frees the store bytes —
    the remaining suffix to prefill is exactly the one token recompute
    would have replayed last, so greedy streams resume bit-exactly.

    Reads the engine's live ``caches`` attribute through a backref (the
    pytree is replaced functionally every step); ``shard`` selects one
    shard's slice for the sharded engine (one store per shard, so
    ``used`` accounting matches the per-shard scheduler's victims)."""

    def __init__(self, engine, capacity_bytes: int,
                 shard: Optional[int] = None):
        self._engine = engine
        self.capacity = capacity_bytes
        self.shard = shard
        self.used = 0

    def save(self, req: Request, pages: List[int], slot: int) -> bool:
        data = PC.gather_pages_host(self._engine.caches, pages,
                                    shard=self.shard)
        ring = PC.gather_ring_rows(self._engine.caches, slot,
                                   shard=self.shard)
        nbytes = (sum(v.nbytes for v in data.values())
                  + sum(v.nbytes for v in ring.values()))
        if self.used + nbytes > self.capacity:
            return False
        self.drop(req)
        req.swap_data = {"pages": data, "ring": ring,
                         "n_tokens": req.cache_len, "nbytes": nbytes}
        self.used += nbytes
        return True

    def drop(self, req: Request) -> None:
        if req.swap_data is not None:
            self.used -= req.swap_data["nbytes"]
            req.swap_data = None


def drain_cache_ops(caches, sched: Scheduler, swap_store, page_size: int,
                    shard: Optional[int] = None):
    """Apply the scheduler's planned device cache ops, in order: COW
    page copies (sources pinned since scheduling, so FIFO application
    reads them before any reuse), swap restores, key-conv ring loads.
    Returns the updated cache pytree; restores also set the request's
    ``cache_len`` so the takes computed at prefill see the restored
    prefix."""
    ops = sched.take_cache_ops()
    # one copy per call: the op shape stays (1,) no matter how many COWs
    # a step batches, so the traced copy compiles exactly once
    for s, d in ops["copies"]:
        caches = PC.copy_pages(caches, [s], [d], shard=shard)
    for req in ops["restores"]:
        sd = req.swap_data
        pages = sched._seq_pages[req.slot][
            :math.ceil(sd["n_tokens"] / page_size)]
        caches = PC.scatter_pages_device(caches, pages, sd["pages"],
                                         shard=shard)
        if sd["ring"]:
            caches = PC.scatter_ring_rows(caches, req.slot, sd["ring"],
                                          shard=shard)
        req.cache_len = sd["n_tokens"]
        swap_store.drop(req)
        sched.stats["swap_restores"] += 1
    for sl, pg in ops["ring_loads"]:        # same shape-stability story
        caches = PC.load_ring_from_tails(caches, [sl], [pg], shard=shard)
    return caches


def unsupported_reason(cfg: ModelConfig) -> Optional[Tuple[str, str]]:
    """(feature, reason) the paged engine cannot serve, or None.

    Key-conv configs are no longer rejected here: the per-slot raw-key
    ring buffer (DESIGN.md §4) made them a backend *capability* — the
    admission-time capability query in :class:`Engine` checks the chosen
    backend declares paged key-conv support instead."""
    bad = [k for k in cfg.layer_pattern
           if k not in ("dense", "swa", "moba", "shared_attn")]
    if bad:
        return ("layer_pattern",
                f"slots {bad} have no paging granularity; use the "
                f"fixed-batch loop")
    if cfg.family not in ("dense", "moe"):
        return ("family",
                f"family {cfg.family!r} is not engine-supported; use "
                f"the fixed-batch loop")
    return None


def engine_supported(cfg: ModelConfig) -> bool:
    return unsupported_reason(cfg) is None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_seqs: int = 8                  # concurrent sequence slots
    max_seq_len: int = 512             # per-sequence prompt+gen capacity
    num_pages: int = 0                 # 0 → max_seqs * pages_per_seq
    page_size: int = 0                 # 0 → MoBA block size (or 16)
    max_prefill_batch: int = 4
    prefill_chunk: int = 0             # split prompts into chunks of this
    #                                    many tokens across engine steps
    #                                    (0 = whole-prompt prefill)
    prefix_cache: bool = False         # radix-tree prefix cache: admission
    #                                    maps cached pages (refcount++) and
    #                                    prefills only the suffix, COWing a
    #                                    shared partial tail page
    swap_bytes: int = 64 << 20         # host-memory cap (per shard) for
    #                                    swap-based preemption; 0 = always
    #                                    recompute preempted prefixes
    kv_dtype: str = "fp32"             # paged-pool K/V storage: "fp32"
    #                                    (compute dtype, no scales) or
    #                                    quantized "int8" / "fp8" with
    #                                    per-page fp32 scales; routing
    #                                    (centroids, key-conv state)
    #                                    stays fp32 either way
    #                                    (core/quantization.py)
    route_policy: str = "static"       # MoBA routing policy: "static"
    #                                    (uniform top_k), "snr:pfail=P"
    #                                    (calibrate per-(layer, head)
    #                                    top_k from measured SNR at
    #                                    engine construction), or
    #                                    "profile:PATH" (load a saved
    #                                    routing-profile artifact) —
    #                                    core/adaptive.py, DESIGN.md §8
    attn_backend: str = ""             # registered backend (core.backends);
    #                                    "" → "reference" ("sharded" for
    #                                    the sharded engine).  A
    #                                    "name:option,..." spec (e.g.
    #                                    "flash:compiled" or
    #                                    "flash:flat,kb_tile=64")
    #                                    configures the registry instance
    #                                    PROCESS-WIDE — the last spec
    #                                    parsed wins for every engine
    #                                    sharing the process
    dispatch_ahead: int = 1            # decode steps the host may enqueue
    #                                    before blocking on the oldest
    #                                    one's tokens (generate_step
    #                                    pipelining; 0 = fully
    #                                    synchronous).  The legacy
    #                                    step()/run() driver drains every
    #                                    iteration regardless.
    # moba_impl was removed (the long-deprecated alias for attn_backend);
    # the InitVar keeps the keyword rejectable with a shaped error
    # instead of a bare TypeError
    moba_impl: dataclasses.InitVar[Optional[str]] = None

    def __post_init__(self, moba_impl):
        if moba_impl:
            raise UnsupportedFeatureError(
                "moba_impl",
                f"EngineConfig.moba_impl was removed; pass "
                f"attn_backend={moba_impl!r} instead (same values — see "
                f"core.backends.resolve_backend_spec)")


@dataclasses.dataclass
class Prefix:
    """Handle returned by :meth:`Engine.prefill`: the request's whole
    context is cached in the paged pool at ``slot`` and its first token
    is sampled (``token`` — stream it immediately; it is the TTFT
    token).  Pass to :meth:`Engine.insert` to join the decode batch.
    The handle goes stale if the request is preempted before insertion
    (``insert`` then returns False and the caller re-prefills)."""
    req: Request
    token: int
    slot: int
    shard: int = -1    # owning shard (sharded engine); -1 = single-host


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig = None,
                 ):
        reason = unsupported_reason(cfg)
        if reason is not None:
            raise UnsupportedFeatureError(*reason)
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg = ecfg or EngineConfig()
        # one resolver for every surface (CLIs included): empty spec
        # falls back to the engine default; options ("flash:compiled")
        # are applied to the backend instance here
        self.attn_backend = resolve_engine_backend(ecfg.attn_backend,
                                                   "reference")
        if ecfg.dispatch_ahead < 0:
            raise ServingError(
                f"dispatch_ahead must be >= 0, got {ecfg.dispatch_ahead}")
        if ecfg.kv_dtype not in Q.KV_DTYPES:
            raise ServingError(
                f"unknown kv_dtype {ecfg.kv_dtype!r}; "
                f"expected one of {Q.KV_DTYPES}")
        from repro.core.adaptive import parse_route_policy
        try:
            route_mode, _ = parse_route_policy(ecfg.route_policy)
        except ValueError as e:
            raise UnsupportedFeatureError("route_policy", str(e)) from e
        admission_capability_check(cfg, self.attn_backend,
                                   kv_dtype=ecfg.kv_dtype,
                                   adaptive=route_mode != "static")
        self.page_size, self.pages_per_seq, self.num_pages = \
            resolve_pool_sizes(cfg, ecfg)
        # adaptive routing: calibrate (or load) the per-(layer, head)
        # top_k profile once at construction; the step functions embed it
        # as a closure constant, so every prefill/decode — including
        # preempt-swap-restore replays — routes from the same profile
        self.route_profile, route_map = build_route_profile(
            cfg, params, ecfg.route_policy, self.pages_per_seq)
        conv = needs_key_conv(cfg)
        if ecfg.prefix_cache and conv \
                and cfg.attention.moba.key_conv_width - 1 > self.page_size:
            raise ServingError(
                f"prefix cache needs key_conv_width - 1 "
                f"({cfg.attention.moba.key_conv_width - 1}) <= page_size "
                f"({self.page_size}): ring state restores from one "
                f"page's raw-key tail")
        self.caches = T.init_paged_caches(
            cfg, self.num_pages, self.page_size,
            dtype=jnp.dtype(cfg.dtype), max_seqs=ecfg.max_seqs,
            prefix_tails=ecfg.prefix_cache and conv,
            kv_dtype=ecfg.kv_dtype)
        self.swap_store = (HostSwapStore(self, ecfg.swap_bytes)
                           if ecfg.swap_bytes > 0 else None)
        self.sched = Scheduler(
            num_pages=self.num_pages, page_size=self.page_size,
            max_seqs=ecfg.max_seqs, max_pages_per_seq=self.pages_per_seq,
            max_prefill_batch=ecfg.max_prefill_batch,
            chunk_tokens=ecfg.prefill_chunk,
            prefix_cache=ecfg.prefix_cache, key_conv=conv,
            full_page_match=ecfg.kv_dtype != "fp32",
            swap=self.swap_store)
        # prefix hits and swap restores resume mid-context, so their
        # suffix prefills need the chunk-aware (kv_len-offset) path even
        # when chunked prefill itself is off
        self._chunk_aware = bool(ecfg.prefill_chunk or ecfg.prefix_cache
                                 or ecfg.swap_bytes > 0)
        self._prefill = jax.jit(
            S.make_paged_prefill_step(cfg, backend=self.attn_backend,
                                      chunked=self._chunk_aware,
                                      route_map=route_map),
            donate_argnums=(2,))
        self._decode = jax.jit(
            S.make_paged_decode_step(cfg, backend=self.attn_backend,
                                     route_map=route_map),
            donate_argnums=(2,))
        self._cur_tok = np.zeros((ecfg.max_seqs,), np.int32)
        self._next_rid = 0
        self._t0 = None
        self.finished: List[Request] = []
        # dispatch-ahead pipeline: (batch membership, device tokens) per
        # dispatched-but-unobserved decode step, oldest first.  _tok_dev
        # is the device-resident current-token vector the chain feeds on
        # (None = rebuild from the host copy, which is only safe when
        # the pipeline is empty).
        self._inflight: Deque[Tuple[List[Request], jax.Array]] = \
            collections.deque()
        self._tok_dev = None
        self._emitted: List[Tuple[Request, int]] = []
        self.sched.before_preempt = self._sync_for_preempt
        # perf counters (wall seconds / token counts); the prefix/swap
        # keys mirror the scheduler's counters each step so the dict is
        # one stable, benchmark-consumable schema
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "preemptions": 0,
                      "tree_evictions": 0, "pages_in_use_peak": 0,
                      "dispatch_depth_peak": 0, "pipeline_drains": 0}
        self.stats.update(self.sched.stats)

    # ------------------------------------------------------------- intake
    def make_request(self, prompt: Sequence[int], max_new_tokens: int,
                     arrival: float = 0.0, eos_id: Optional[int] = None
                     ) -> Request:
        """Build (and validate, but do NOT queue) a request — the staged
        intake.  Feed it to :meth:`prefill` when the caller decides, or
        to ``self.sched.submit`` via :meth:`submit` for the legacy
        closed loop."""
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, arrival=arrival,
                      eos_id=eos_id)
        self._next_rid += 1
        self.sched.validate(req)
        return req

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, eos_id: Optional[int] = None
               ) -> Request:
        req = self.make_request(prompt, max_new_tokens, arrival=arrival,
                                eos_id=eos_id)
        self.sched.submit(req)
        return req

    # -------------------------------------------------------------- steps
    def _bucket(self, n: int) -> int:
        return prefill_bucket(n, self.page_size)

    def _takes(self, reqs: List[Request]) -> List[int]:
        return prefill_takes(reqs, self.ecfg.prefill_chunk)

    def _run_prefill(self, reqs: List[Request], now: float) -> None:
        """One ragged prefill batch: each row is a request's whole context
        (one-shot mode) or its next ``prefill_chunk`` tokens (chunked
        mode, with ``kv_len`` carrying the chunk offset).  Only rows whose
        context completes this step record the sampled token and join
        decoding."""
        takes = self._takes(reqs)
        lmax = self._bucket(max(takes))
        tokens, kv_len, q_len, slots, active, table = build_prefill_batch(
            self.sched, reqs, takes, self.ecfg.max_prefill_batch,
            self.pages_per_seq, lmax)
        t0 = time.perf_counter()
        tok, self.caches = self._prefill(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(table), jnp.asarray(kv_len), jnp.asarray(q_len),
            jnp.asarray(slots), jnp.asarray(active))
        tok = np.asarray(tok)
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += int(sum(takes))
        record_prefill(reqs, takes, tok, self._cur_tok, self._wall())

    def _wall(self) -> float:
        return (0.0 if self._t0 is None
                else time.perf_counter() - self._t0)

    # ------------------------------------------- dispatch-ahead pipeline
    def _dispatch_decode(self, reqs: List[Request]) -> None:
        """Enqueue one jitted decode step over ``reqs`` WITHOUT blocking
        on its tokens.  The current-token vector chains on device
        (``jnp.where`` keeps inactive slots), so back-to-back dispatches
        never round-trip through the host."""
        kv_len, active = build_decode_batch(reqs, self.ecfg.max_seqs)
        if self._tok_dev is None:       # pipeline empty: host copy is
            self._tok_dev = jnp.asarray(self._cur_tok)   # authoritative
        t0 = time.perf_counter()
        tok, self.caches = self._decode(
            self.params, self._tok_dev, self.caches,
            jnp.asarray(self.sched.block_table), jnp.asarray(kv_len),
            jnp.asarray(active))
        self._tok_dev = jnp.where(jnp.asarray(active), tok, self._tok_dev)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        for r in reqs:
            r.dispatched += 1
        self._inflight.append((list(reqs), tok))
        self.stats["dispatch_depth_peak"] = max(
            self.stats["dispatch_depth_peak"], len(self._inflight))

    def _observe_one(self) -> None:
        """Block on the OLDEST in-flight decode step and fold its tokens
        into host state.  Requests that hit EOS at an earlier
        observation skip recording: their overrun steps computed (and
        wrote KV for) garbage past the stream's end, all inside pages
        still reserved for them and past every offset the prefix tree
        publishes — discarded, not replayed."""
        reqs, tok_dev = self._inflight.popleft()
        t0 = time.perf_counter()
        tok = np.asarray(tok_dev)       # the only host-device sync point
        self.stats["decode_s"] += time.perf_counter() - t0
        for r in reqs:
            r.dispatched -= 1
            if r.state != "running" or r.done:
                continue
            r.cache_len += 1
            t = int(tok[r.slot])
            r.out.append(t)
            self._cur_tok[r.slot] = t
            self.stats["decode_tokens"] += 1
            if r.t_first is None:
                r.t_first = self._wall()
            if self.ecfg.prefix_cache \
                    and r.cache_len % self.page_size == 0:
                self.sched.note_cached(r)   # page-boundary crossing
            self._emitted.append((r, t))
        if not self._inflight:
            # pipeline empty → the host vector is authoritative again;
            # drop the device chain so the next dispatch rebuilds it
            # (new tenants of recycled slots get their prefill token,
            # not the previous occupant's last one)
            self._tok_dev = None

    def drain(self) -> None:
        """Observe every in-flight decode step.  Afterwards host
        bookkeeping (``cache_len``, ``out``, ``_cur_tok``) is consistent
        with device state — required before preemption snapshots, and
        what the legacy ``step()`` does each iteration for synchronous
        semantics."""
        if self._inflight:
            self.stats["pipeline_drains"] += 1
        while self._inflight:
            self._observe_one()

    def _sync_for_preempt(self) -> None:
        """``Scheduler.before_preempt`` hook: drain the pipeline and
        retire finished requests (freeing their pages) so preemption
        decisions see host-consistent state — and may become moot."""
        self.drain()
        self._finish_done()

    def _finish_done(self) -> None:
        for r in [r for r in self.sched.running
                  if r.state == "running" and r.done
                  and r.dispatched == 0]:
            self.sched.finish(r)
            r.t_done = self._wall()
            self.finished.append(r)

    def _update_stats(self) -> None:
        self.stats.update(self.sched.stats)
        if self.sched.tree is not None:
            self.stats["tree_evictions"] = self.sched.tree.evictions
        self.stats["pages_in_use_peak"] = max(
            self.stats["pages_in_use_peak"],
            self.num_pages - self.sched.alloc.available)

    # ------------------------------------------------------------- stages
    def prefill(self, req: Request, now: float = float("inf")
                ) -> Optional[Prefix]:
        """Stage 1: admit ``req``, cache its whole context (all chunks
        under chunked prefill, with admission's COW copies / swap
        restores / ring loads applied first) and sample its first token.
        Returns None when the pool or slots cannot host it right now —
        retry after :meth:`generate_step` frees capacity.  Accepts both
        fresh requests (:meth:`make_request`) and preempted ones waiting
        for replay; pipeline-safe, so admission never stalls decode."""
        if req.state not in ("waiting",) or req.slot >= 0:
            raise ServingError(
                f"request {req.rid}: prefill() on state {req.state!r} "
                f"(slot {req.slot}); only waiting requests stage")
        if self._t0 is None:
            self._t0 = time.perf_counter()
        queued = req in self.sched.waiting      # preemption replay
        if queued:
            self.sched.waiting.remove(req)
        ok = self.sched.admit(req)
        if not ok:
            # finished-but-unobserved requests may be holding the pages
            self._sync_for_preempt()
            ok = self.sched.admit(req)
        if not ok:
            if queued:      # keep the victim's replay priority
                self.sched.waiting.appendleft(req)
            return None
        # snapshot: the final chunk's record_prefill appends the sampled
        # token to ``out``, growing ``context`` by one — the live value
        # would never satisfy the loop condition
        target = len(req.context)
        first = True
        while req.cache_len < target:
            if not first:
                # between chunks the plan-time tail-ownership guarantee:
                # cannot fail, every page was reserved at admission
                ok = self.sched._cow_tail(req)
                assert ok, "chunk continuation pages reserved at admission"
            self.caches = drain_cache_ops(self.caches, self.sched,
                                          self.swap_store, self.page_size)
            self._run_prefill([req], now)
            self.sched.note_cached(req)
            first = False
        req.state = "prefilled"
        self._update_stats()
        return Prefix(req=req, token=int(req.out[-1]), slot=req.slot)

    def insert(self, prefix: Prefix, slot: Optional[int] = None) -> bool:
        """Stage 2: bind a prefilled request into the decode batch.
        Returns False when the handle went stale (the request was
        preempted between prefill and insert — re-prefill it).  ``slot``
        is accepted for API symmetry but must match the slot admission
        bound at prefill: pages were written there."""
        req = prefix.req
        if slot is not None and slot != req.slot:
            raise ServingError(
                f"request {req.rid}: insert at slot {slot} but its pages "
                f"live at slot {req.slot}; slots bind at prefill")
        if req.state != "prefilled":
            return False
        req.state = "running"
        tok = int(req.out[-1])
        self._cur_tok[req.slot] = tok
        if self._tok_dev is not None:   # patch mid-pipeline: in-flight
            # steps never reference this slot, so a point update is safe
            self._tok_dev = self._tok_dev.at[req.slot].set(tok)
        return True

    def generate_step(self, now: float = float("inf")
                      ) -> List[Tuple[Request, int]]:
        """Stage 3: plan growth/preemption over the bound slots,
        dispatch one decode step, and return the ``(request, token)``
        pairs observed this call.  With ``dispatch_ahead > 0`` the
        dispatched step is only awaited once more than that many are in
        flight, so tokens surface one pipeline-depth later (keep
        calling with an empty batch to flush the tail).  Tokens per
        request are identical to the legacy ``run()`` loop's — greedy
        decode is independent of batch composition."""
        preempted = self.sched.plan_decode(now)
        self.stats["preemptions"] += len(preempted)
        self.caches = drain_cache_ops(self.caches, self.sched,
                                      self.swap_store, self.page_size)
        decodes = [r for r in self.sched.running
                   if r.state == "running" and not r.budget_spent]
        if decodes:
            self._dispatch_decode(decodes)
        depth = self.ecfg.dispatch_ahead if decodes else 0
        while len(self._inflight) > depth:
            self._observe_one()
        self._finish_done()
        self._update_stats()
        out, self._emitted = self._emitted, []
        return out

    def has_work(self) -> bool:
        """Queued, running, or in-flight work remains (in-flight counts:
        the pipeline tail still owes observations)."""
        return self.sched.has_work() or bool(self._inflight)

    @property
    def preempted_waiting(self) -> List[Request]:
        """Preemption victims awaiting re-prefill, in replay order —
        the staged driver's signal to call :meth:`prefill` again (the
        legacy loop re-admits them itself)."""
        return [r for r in self.sched.waiting if r.n_preempt > 0]

    # ------------------------------------------------- legacy closed loop
    def step(self, now: float = float("inf")) -> Dict:
        """One legacy engine iteration, now a thin driver over the
        stages: admit + prefill (applying COW copies, swap restores and
        ring loads the plan scheduled), dispatch one decode step over
        all running, observe it synchronously."""
        self.drain()    # synchronous semantics if stages interleaved
        preempted = self.sched.plan_decode(now)
        self.stats["preemptions"] += len(preempted)
        prefills = self.sched.plan_prefills(now)
        self.caches = drain_cache_ops(self.caches, self.sched,
                                      self.swap_store, self.page_size)
        if prefills:
            self._run_prefill(prefills, now)
            for r in prefills:            # newly cached full pages join
                self.sched.note_cached(r)  # the prefix tree immediately
        # recomputed after prefill so every request whose context
        # completed this step — one-shot admissions and final chunks
        # alike — joins the decode batch in the same iteration
        decodes = [r for r in self.sched.running
                   if r.state == "running" and not r.budget_spent]
        if decodes:
            self._dispatch_decode(decodes)
            self.drain()
        n0 = len(self.finished)
        self._finish_done()
        n_done = len(self.finished) - n0
        self._emitted.clear()      # step() reports counts, not streams
        self._update_stats()
        return {"prefilled": len(prefills), "decoded": len(decodes),
                "finished": n_done, "preempted": len(preempted)}

    # ---------------------------------------------------------------- run
    def run(self, realtime: bool = False) -> List[Request]:
        """Drain all submitted requests and return the ones finished by
        *this* call (``self.finished`` keeps the engine-lifetime list).
        ``realtime=True`` honours request arrival times against the wall
        clock (Poisson streams); otherwise every step sees every queued
        request."""
        n0 = len(self.finished)
        if self._t0 is None:     # keep one clock base across run() calls
            self._t0 = time.perf_counter()
        while self.has_work():
            now = self._wall() if realtime else float("inf")
            self.step(now=now)
            if realtime and not self.sched.running \
                    and self.sched.waiting:
                wait = self.sched.waiting[0].arrival - self._wall()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return self.finished[n0:]
