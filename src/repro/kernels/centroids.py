"""Pallas kernel: fused key-block centroid computation (paper Alg. 2).

Grid (heads, n_blocks); each step loads one (B, d) key block into VMEM and
reduces it to its (1, d) mean.  Output is B× smaller than K — the point of
the fusion is that subsequent routing reads K̃, not K.

TPU notes: block shapes are (1, B, d) with d MXU-lane-aligned; reduction
runs on the VPU in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _centroid_kernel(k_ref, out_ref, *, block_size: int, n_tokens: int):
    j = pl.program_id(1)
    kb = k_ref[0].astype(jnp.float32)                     # (B, d)
    # mask the ragged tail block (positions >= n_tokens)
    pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (block_size, 1), 0)
    valid = (pos < n_tokens).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(valid), 1.0)
    out_ref[0] = (jnp.sum(kb * valid, axis=0, keepdims=True)
                  / denom).astype(out_ref.dtype)


def block_centroids_kernel(k: jax.Array, block_size: int,
                           interpret: bool | None = None) -> jax.Array:
    """k: (H, N, d) -> (H, nb, d).  N padded to a block multiple by caller
    or handled via the ragged-tail mask here."""
    interpret = resolve_interpret(interpret)
    h, n, d = k.shape
    nb = -(-n // block_size)
    pad = nb * block_size - n
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
    return pl.pallas_call(
        functools.partial(_centroid_kernel, block_size=block_size,
                          n_tokens=n),
        grid=(h, nb),
        in_specs=[pl.BlockSpec((1, block_size, d),
                               lambda hh, j: (hh, j, 0))],
        out_specs=pl.BlockSpec((1, 1, d), lambda hh, j: (hh, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, nb, d), k.dtype),
        interpret=interpret,
    )(k)
