"""Pallas kernel: flash sliding-window attention (the paper's odd layers).

The hybrid recipe (§5.1) interleaves SWA(256)+RoPE with MoBA layers; this
kernel covers the SWA half with FlashAttention-2 mechanics restricted to
the band ``q_pos - window < k_pos <= q_pos``: each query tile visits only
the ⌈(window+Tq)/Tk⌉ key tiles that can intersect its band (O(N·w)
instead of O(N²)), with online-softmax stats in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, window: int, q_tile: int, k_tile: int,
                n_kv_tiles: int, n_tokens: int, steps: int):
    qt = pl.program_id(1)
    st = pl.program_id(2)

    @pl.when(st == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (Tq, d)
    k = k_ref[0].astype(jnp.float32)                 # (Tk, d)
    v = v_ref[0].astype(jnp.float32)

    # which kv tile is this step actually visiting (mirrors the index_map)
    first_tile = jnp.maximum(qt * q_tile - (window - 1), 0) // k_tile
    unclamped = first_tile + st
    kv_tile = jnp.minimum(unclamped, n_kv_tiles - 1)

    qpos = (qt * q_tile
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 0))
    kpos = (kv_tile * k_tile
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 1))
    mask = ((kpos <= qpos) & (qpos - kpos < window)
            & (kpos < n_tokens) & (qpos < n_tokens)
            # clamped steps re-visit the last tile — contribute nothing
            & (unclamped < n_kv_tiles))

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    alpha = jnp.exp(jnp.maximum(m_prev, NEG_INF / 2) - m_safe)
    p = jnp.exp(s - m_safe[:, None]) * mask.astype(jnp.float32)
    l_new = l_scr[...][:, 0] * alpha + jnp.sum(p, axis=1)
    acc = (acc_scr[...] * alpha[:, None]
           + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32))
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]
    acc_scr[...] = acc

    @pl.when(st == steps - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, window: int,
                  *, num_q_heads: int = 0, group: int = 1,
                  scale: Optional[float] = None, q_tile: int = 128,
                  k_tile: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """q: (BH, N, d); k, v: (BKV, N, d); BH = batch·H, BKV = batch·Hkv."""
    interpret = resolve_interpret(interpret)
    bh, n, d = q.shape
    h = num_q_heads or bh
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q_tile = min(q_tile, n)
    k_tile = min(k_tile, n)
    assert n % q_tile == 0 and n % k_tile == 0
    n_kv_tiles = n // k_tile
    # tiles a band of width `window` ending inside a q tile can touch
    steps = min((window - 1 + q_tile - 1) // k_tile + 2, n_kv_tiles)

    def kv_index(bhi, qt, st):
        kv = (bhi // h) * (h // group) + (bhi % h) // group
        first = jnp.maximum(qt * q_tile - (window - 1), 0) // k_tile
        return (kv, jnp.minimum(first + st, n_kv_tiles - 1), 0)

    kernel = functools.partial(
        _swa_kernel, scale=float(scale), window=window, q_tile=q_tile,
        k_tile=k_tile, n_kv_tiles=n_kv_tiles, n_tokens=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=(bh, n // q_tile, steps),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, qt, st: (bhi, qt, 0)),
            pl.BlockSpec((1, k_tile, d), kv_index),
            pl.BlockSpec((1, k_tile, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_tile, d),
                               lambda bhi, qt, st: (bhi, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((q_tile, 1), jnp.float32),
                        pltpu.VMEM((q_tile, 1), jnp.float32),
                        pltpu.VMEM((q_tile, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
