"""Shared compiled-mode tiling contracts for the Pallas kernels.

Mosaic lowers VMEM blocks in (sublane × lane) tiles — (8, 128) for fp32,
(16, 128) for bf16, (32, 128) for int8/fp8.  A block whose trailing two
dims do not decompose into whole tiles either pads silently (wasting
VMEM/bandwidth) or fails deep inside Mosaic with an unshaped error.  The
kernels therefore validate their geometry *here*, before any
``pallas_call``, and raise a shaped ``ValueError`` naming the violating
dimension and the remediation (DESIGN.md §2/§5).

Contracts:

* :func:`check_decode_tiling` — the grouped paged-decode grid
  (``kernels/moba_decode.py``): (page_size, head_dim) pages.
* :func:`check_moba_tiling` — the kb-tiled training grids
  (``kernels/moba_fwd.py`` / ``kernels/moba_bwd.py``): the
  (q_tile, head_dim) query block and the (kb_tile, head_dim) key-block
  tile streamed per grid step.
* :func:`check_topk_tiling` — the grouped Flash-TopK grid
  (``kernels/flash_topk.py``): the (q_tile, cent_tile) score tile and
  the (cent_tile, head_dim) centroid block.

Interpret mode (`kernels/runtime.py`) accepts any shape and never calls
these — CPU CI runs the small test geometries there.
"""
from __future__ import annotations

import jax.numpy as jnp

LANE = 128      # TPU lane count: last block dim must be a multiple
SUBLANE = 8     # fp32 sublane grain; dtype grain = 8 * (4 // itemsize)


def round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def default_kb_tile(block_size: int) -> int:
    """Auto K/V streaming granularity for the kb-tiled training grids:
    one lane-width slice, or the whole block when it is smaller (small
    blocks mask-pad instead of splitting)."""
    return min(block_size, LANE)


def sublane(dtype) -> int:
    """Sublane grain of the (sublane × 128) tile for ``dtype``: 8 for
    fp32 (and any wider dtype), 16 for bf16, 32 for int8/fp8."""
    return SUBLANE * max(1, 4 // jnp.dtype(dtype).itemsize)


def _fail(kernel: str, problems: list) -> None:
    raise ValueError(
        f"compiled {kernel} kernel tiling contract violated: "
        + "; ".join(problems)
        + " — choose a conforming geometry or run interpret mode "
          "(REPRO_PALLAS_INTERPRET=1)")


def check_decode_tiling(page_size: int, head_dim: int, dtype) -> None:
    """Compiled-mode tiling contract for the grouped decode grid: the
    (ps, d) page block must decompose into whole (sublane, 128) tiles.
    Raises with a remediation hint; interpret mode never calls this."""
    sub = sublane(dtype)
    if page_size % sub or head_dim % LANE:
        raise ValueError(
            f"compiled paged-decode kernel needs ({sub}, {LANE})-tileable "
            f"pages for dtype {jnp.dtype(dtype).name}: page_size="
            f"{page_size} must be a multiple of {sub} and head_dim="
            f"{head_dim} a multiple of {LANE} (got page_size % {sub} == "
            f"{page_size % sub}, head_dim % {LANE} == {head_dim % LANE}); "
            f"choose a conforming pool geometry or run interpret mode "
            f"(REPRO_PALLAS_INTERPRET=1)")


def check_moba_tiling(block_size: int, kb_tile: int, q_tile: int,
                      head_dim: int, dtype) -> None:
    """Compiled-mode tiling contract for the kb-tiled training grids
    (``moba_fwd`` / ``moba_bwd``): every VMEM block the grid streams —
    the (q_tile, d) query tile, the (kb_tile, d) key-block tile, and the
    (q_tile, kb_tile) score tile — must decompose into whole
    (sublane, 128) tiles, and ``kb_tile`` must evenly split the key
    block so the kb grid dimension covers it exactly."""
    sub = sublane(dtype)
    name = jnp.dtype(dtype).name
    problems = []
    if head_dim % LANE:
        problems.append(
            f"head_dim={head_dim} must be a multiple of {LANE} (the TPU "
            f"lane count); got head_dim % {LANE} == {head_dim % LANE}")
    if q_tile % sub:
        problems.append(
            f"q_tile={q_tile} must be a multiple of the {name} sublane "
            f"grain {sub}; got q_tile % {sub} == {q_tile % sub}")
    if kb_tile % sub:
        problems.append(
            f"kb_tile={kb_tile} must be a multiple of the {name} sublane "
            f"grain {sub}; got kb_tile % {sub} == {kb_tile % sub}")
    if kb_tile % LANE and kb_tile != block_size:
        problems.append(
            f"kb_tile={kb_tile} is the lane dim of the (q_tile, kb_tile) "
            f"score tile and must be a multiple of {LANE} when it splits "
            f"the key block (kb_tile == block_size is exempt: small-block "
            f"configs mask-pad instead); got kb_tile % {LANE} == "
            f"{kb_tile % LANE}")
    if block_size % kb_tile:
        problems.append(
            f"kb_tile={kb_tile} must evenly divide block_size="
            f"{block_size} so the kb grid dimension covers the key block "
            f"exactly; got block_size % kb_tile == "
            f"{block_size % kb_tile}")
    if problems:
        _fail("moba fwd/bwd", problems)


def check_topk_tiling(cent_tile: int, q_tile: int, head_dim: int,
                      dtype) -> None:
    """Compiled-mode tiling contract for the grouped Flash-TopK grid:
    the (cent_tile, d) centroid block and the (G·q_tile, cent_tile)
    score tile must decompose into whole (sublane, 128) tiles, and
    ``cent_tile`` must be a power of two (the tile-local bitonic
    tournament folds candidate lanes in halves)."""
    sub = sublane(dtype)
    name = jnp.dtype(dtype).name
    problems = []
    if head_dim % LANE:
        problems.append(
            f"head_dim={head_dim} must be a multiple of {LANE} (the TPU "
            f"lane count); got head_dim % {LANE} == {head_dim % LANE}")
    if q_tile % sub:
        problems.append(
            f"q_tile={q_tile} must be a multiple of the {name} sublane "
            f"grain {sub}; got q_tile % {sub} == {q_tile % sub}")
    if cent_tile % LANE:
        problems.append(
            f"cent_tile={cent_tile} is the lane dim of the score tile "
            f"and must be a multiple of {LANE}; got cent_tile % {LANE} "
            f"== {cent_tile % LANE}")
    if cent_tile & (cent_tile - 1):
        problems.append(
            f"cent_tile={cent_tile} must be a power of two (the bitonic "
            f"tournament folds candidate lanes in halves)")
    if problems:
        _fail("flash_topk", problems)
