"""Pallas kernel: FlashMoBA backward (paper Alg. 5, TPU adaptation).

Key-block-parallel with recomputation: each tile re-derives its attention
probabilities from (Q_sorted, K_j, lse) — the attention matrix is never
stored.  dK_j/dV_j accumulate in the *output VMEM buffer* across the
consecutive tiles of block j (the sorted layout guarantees a block's tiles
are contiguous, which is the TPU-native replacement for the paper's
per-thread-block ownership), and partial dQ is written per-slot and
segment-summed by the wrapper — the deterministic replacement for CUDA
atomicAdd into dQ_accum.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _bwd_kernel(tb_ref, qs_ref, qpos_ref, do_ref, lse_ref, delta_ref,
                k_ref, v_ref, dq_ref, dk_ref, dv_ref, *,
                scale: float, block_size: int, n_blocks: int,
                n_tokens: int, causal: bool):
    bh = pl.program_id(0)
    t = pl.program_id(1)
    blk = tb_ref[bh, t]
    prev_blk = tb_ref[bh, jnp.maximum(t - 1, 0)]
    mapped = jnp.minimum(blk, n_blocks - 1)
    prev_mapped = jnp.minimum(prev_blk, n_blocks - 1)
    is_first = (t == 0) | (mapped != prev_mapped)

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    do = do_ref[0].astype(jnp.float32)           # (Tq, d)
    kb = k_ref[0, 0].astype(jnp.float32)         # (B, d)
    vb = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = (blk * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (tq, block_size), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    # true post-merge probabilities: exp(s - lse_q)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)     # (Tq, B)

    dv_c = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (B, d)
    dp = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (Tq, B)
    ds = p * (dp - delta[:, None]) * scale
    dq_c = jax.lax.dot_general(ds, kb, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Tq, d)
    dk_c = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (B, d)

    dq_ref[0] = dq_c

    @pl.when(is_first)
    def _init():
        dk_ref[0, 0] = dk_c
        dv_ref[0, 0] = dv_c

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        dk_ref[0, 0] += dk_c
        dv_ref[0, 0] += dv_c


def moba_bwd(tile_block: jax.Array, q_sorted: jax.Array, q_pos: jax.Array,
             do_sorted: jax.Array, lse_sorted: jax.Array,
             delta_sorted: jax.Array, k_blocks: jax.Array,
             v_blocks: jax.Array, *, scale: float, block_size: int,
             n_tokens: int, num_q_heads: int, group: int,
             causal: bool = True, q_tile: int = 128,
             interpret: bool | None = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Backward over flattened (batch·head) layouts.

    Returns (dq_sorted (BH,L,d), dk (BH,nb,B,d), dv (BH,nb,B,d)) — all f32;
    dk/dv are per *query head* and must be (a) masked by per-block visit
    flags (unvisited blocks hold garbage) and (b) reduced over the GQA
    group by the wrapper.
    """
    interpret = resolve_interpret(interpret)
    bh, L, d = q_sorted.shape
    bkv, nb, bs, _ = k_blocks.shape
    n_tiles = L // q_tile
    h = num_q_heads

    def kv_index(bhi, t, tb_ref):
        kv = (bhi // h) * (h // group) + (bhi % h) // group
        blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
        return (kv, blk, 0, 0)

    def dkv_index(bhi, t, tb_ref):
        return (bhi, jnp.minimum(tb_ref[bhi, t], nb - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_tiles),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            pl.BlockSpec((1, 1, bs, d), kv_index),
            pl.BlockSpec((1, 1, bs, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
            pl.BlockSpec((1, 1, bs, d), dkv_index),
            pl.BlockSpec((1, 1, bs, d), dkv_index),
        ],
    )
    kernel = functools.partial(
        _bwd_kernel, scale=scale, block_size=block_size, n_blocks=nb,
        n_tokens=n_tokens, causal=causal)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, nb, bs, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, nb, bs, d), jnp.float32),
        ],
        interpret=interpret,
    )(tile_block, q_sorted, q_pos, do_sorted, lse_sorted, delta_sorted,
      k_blocks, v_blocks)
