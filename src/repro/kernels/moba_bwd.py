"""Pallas kernel: FlashMoBA backward (paper Alg. 5, TPU adaptation).

Key-block-parallel with recomputation: each tile re-derives its attention
probabilities from (Q_sorted, K_j, lse) — the attention matrix is never
stored.  dK_j/dV_j accumulate in the *output VMEM buffer* across the
consecutive tiles of block j (the sorted layout guarantees a block's tiles
are contiguous, which is the TPU-native replacement for the paper's
per-thread-block ownership), and partial dQ is written per-slot and
segment-summed by the wrapper — the deterministic replacement for CUDA
atomicAdd into dQ_accum.

Two grids:

* ``grouped`` (default, kb-tiled): grid (BH, T, nkb) streams
  (kb_tile, d) K/V slices (double-buffered by the Pallas pipeline).
  Per-tile dK/dV accumulate slice-wise in (B, d) VMEM scratch; at the
  last kb step the tile's contribution merges into the resident
  full-block output window — the dk/dv window index depends only on
  the tile's block id, never on kb, so windows are written exactly once
  per residency and never revisited.
* ``flat`` (legacy, kept selectable for bisection): grid (BH, T) with
  whole-(B, d) K/V blocks per step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret
from repro.kernels.tiling import check_moba_tiling, default_kb_tile

NEG_INF = -1e30


def _bwd_kernel(tb_ref, qs_ref, qpos_ref, do_ref, lse_ref, delta_ref,
                k_ref, v_ref, dq_ref, dk_ref, dv_ref, *,
                scale: float, block_size: int, n_blocks: int,
                n_tokens: int, causal: bool):
    """Legacy flat grid: one whole key block per step."""
    bh = pl.program_id(0)
    t = pl.program_id(1)
    blk = tb_ref[bh, t]
    prev_blk = tb_ref[bh, jnp.maximum(t - 1, 0)]
    mapped = jnp.minimum(blk, n_blocks - 1)
    prev_mapped = jnp.minimum(prev_blk, n_blocks - 1)
    is_first = (t == 0) | (mapped != prev_mapped)

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    do = do_ref[0].astype(jnp.float32)           # (Tq, d)
    kb = k_ref[0, 0].astype(jnp.float32)         # (B, d)
    vb = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = (blk * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (tq, block_size), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    # true post-merge probabilities: exp(s - lse_q)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)     # (Tq, B)

    dv_c = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (B, d)
    dp = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (Tq, B)
    ds = p * (dp - delta[:, None]) * scale
    dq_c = jax.lax.dot_general(ds, kb, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Tq, d)
    dk_c = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (B, d)

    dq_ref[0] = dq_c

    @pl.when(is_first)
    def _init():
        dk_ref[0, 0] = dk_c
        dv_ref[0, 0] = dv_c

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        dk_ref[0, 0] += dk_c
        dv_ref[0, 0] += dv_c


def _bwd_kernel_tiled(tb_ref, qs_ref, qpos_ref, do_ref, lse_ref, delta_ref,
                      k_ref, v_ref, dq_ref, dk_ref, dv_ref,
                      dk_acc, dv_acc, *,
                      scale: float, block_size: int, kb_tile: int,
                      n_kb: int, n_blocks: int, n_tokens: int,
                      causal: bool):
    """kb-tiled grid (BH, T, nkb): recompute + grads per (kb_tile, d)
    K/V slice.  dK/dV slices land in (B, d) VMEM scratch; the tile's
    full-block contribution merges into the resident dk/dv output
    window at the last kb step."""
    bh = pl.program_id(0)
    t = pl.program_id(1)
    kb = pl.program_id(2)
    blk = tb_ref[bh, t]
    prev_blk = tb_ref[bh, jnp.maximum(t - 1, 0)]
    mapped = jnp.minimum(blk, n_blocks - 1)
    prev_mapped = jnp.minimum(prev_blk, n_blocks - 1)
    is_first = (t == 0) | (mapped != prev_mapped)

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    do = do_ref[0].astype(jnp.float32)           # (Tq, d)
    kbt = k_ref[0, 0].astype(jnp.float32)        # (kb_tile, d)
    vbt = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kbt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = (blk * block_size + kb * kb_tile
            + jax.lax.broadcasted_iota(jnp.int32, (tq, kb_tile), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)     # (Tq, kbt)

    dv_c = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, vbt, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    dq_c = jax.lax.dot_general(ds, kbt, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dk_c = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(kb == 0)
    def _dq_init():
        dq_ref[0] = dq_c

    @pl.when(kb > 0)
    def _dq_accum():
        dq_ref[0] += dq_c

    row = kb * kb_tile
    dk_acc[pl.ds(row, kb_tile), :] = dk_c
    dv_acc[pl.ds(row, kb_tile), :] = dv_c

    @pl.when(kb == n_kb - 1)
    def _flush():
        @pl.when(is_first)
        def _init():
            dk_ref[0, 0] = dk_acc[...]
            dv_ref[0, 0] = dv_acc[...]

        @pl.when(jnp.logical_not(is_first))
        def _accum():
            dk_ref[0, 0] += dk_acc[...]
            dv_ref[0, 0] += dv_acc[...]


def moba_bwd(tile_block: jax.Array, q_sorted: jax.Array, q_pos: jax.Array,
             do_sorted: jax.Array, lse_sorted: jax.Array,
             delta_sorted: jax.Array, k_blocks: jax.Array,
             v_blocks: jax.Array, *, scale: float, block_size: int,
             n_tokens: int, num_q_heads: int, group: int,
             causal: bool = True, q_tile: int = 128, kb_tile: int = 0,
             grid: str = "grouped", interpret: bool | None = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Backward over flattened (batch·head) layouts.

    ``grid`` selects the kb-tiled ``grouped`` grid (default) or the
    legacy ``flat`` grid; ``kb_tile`` (grouped only, 0 = auto
    ``min(block_size, 128)``) sets the K/V streaming granularity.

    Returns (dq_sorted (BH,L,d), dk (BH,nb,B,d), dv (BH,nb,B,d)) — all f32;
    dk/dv are per *query head* and must be (a) masked by per-block visit
    flags (unvisited blocks hold garbage) and (b) reduced over the GQA
    group by the wrapper.
    """
    if grid not in ("grouped", "flat"):
        raise ValueError(f"unknown moba_bwd grid {grid!r}: "
                         f"expected 'grouped' or 'flat'")
    interpret = resolve_interpret(interpret)
    bh, L, d = q_sorted.shape
    bkv, nb, bs, _ = k_blocks.shape
    n_tiles = L // q_tile
    h = num_q_heads

    out_shape = [
        jax.ShapeDtypeStruct((bh, L, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, nb, bs, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, nb, bs, d), jnp.float32),
    ]

    if grid == "flat":
        def kv_index(bhi, t, tb_ref):
            kv = (bhi // h) * (h // group) + (bhi % h) // group
            blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
            return (kv, blk, 0, 0)

        def dkv_index(bhi, t, tb_ref):
            return (bhi, jnp.minimum(tb_ref[bhi, t], nb - 1), 0, 0)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, n_tiles),
            in_specs=[
                pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
                pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
                pl.BlockSpec((1, 1, bs, d), kv_index),
                pl.BlockSpec((1, 1, bs, d), kv_index),
            ],
            out_specs=[
                pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
                pl.BlockSpec((1, 1, bs, d), dkv_index),
                pl.BlockSpec((1, 1, bs, d), dkv_index),
            ],
        )
        kernel = functools.partial(
            _bwd_kernel, scale=scale, block_size=block_size, n_blocks=nb,
            n_tokens=n_tokens, causal=causal)
        return pl.pallas_call(
            kernel, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(tile_block, q_sorted, q_pos, do_sorted, lse_sorted, delta_sorted,
          k_blocks, v_blocks)

    kb_tile = min(kb_tile or default_kb_tile(bs), bs)
    if not interpret:
        check_moba_tiling(bs, kb_tile, q_tile, d, k_blocks.dtype)
    assert bs % kb_tile == 0, (bs, kb_tile)
    n_kb = bs // kb_tile

    def kv_index(bhi, t, kb, tb_ref):
        kv = (bhi // h) * (h // group) + (bhi % h) // group
        blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
        return (kv, blk * n_kb + kb, 0, 0)

    def dkv_index(bhi, t, kb, tb_ref):
        # no kb: the window stays resident across a tile's kb run and
        # across the block's contiguous tile run
        return (bhi, jnp.minimum(tb_ref[bhi, t], nb - 1), 0, 0)

    k_t = k_blocks.reshape(bkv, nb * n_kb, kb_tile, d)
    v_t = v_blocks.reshape(bkv, nb * n_kb, kb_tile, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_tiles, n_kb),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, kb, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, kb, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
            pl.BlockSpec((1, 1, kb_tile, d), kv_index),
            pl.BlockSpec((1, 1, kb_tile, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, kb, tb: (bhi, t, 0)),
            pl.BlockSpec((1, 1, bs, d), dkv_index),
            pl.BlockSpec((1, 1, bs, d), dkv_index),
        ],
        scratch_shapes=[
            pltpu.VMEM((bs, d), jnp.float32),
            pltpu.VMEM((bs, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _bwd_kernel_tiled, scale=scale, block_size=block_size,
        kb_tile=kb_tile, n_kb=n_kb, n_blocks=nb, n_tokens=n_tokens,
        causal=causal)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
    )(tile_block, q_sorted, q_pos, do_sorted, lse_sorted, delta_sorted,
      k_t, v_t)
