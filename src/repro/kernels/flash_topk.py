"""Pallas kernel: Flash TopK (paper Alg. 3, TPU adaptation).

Streams tiles of Q against tiles of the centroid matrix K̃ and maintains a
running per-query top-k (scores, block ids) in VMEM scratch — the full
(Nq × nb) score matrix never exists in HBM.

GPU→TPU adaptation: the paper's per-thread bubble sort becomes a k-pass
masked max-extraction over the (running ∪ candidate) score tile — each pass
is one VPU-wide max + compare, with a cumsum tie-break; no per-lane
data-dependent control flow.

Selection semantics (must match `repro.core.routing.select_blocks`):
  * future blocks masked to −inf
  * own block forced to +inf (always selected, counts toward k)
  * slots with score ≤ −inf/2 are sentinels (block id = nb)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30       # mask level (matches core.routing)
EXTRACTED = -2e30     # strictly below mask level: never re-picked as valid
INIT = -3e30
POS_INF = 1e30


def _topk_update(run_s, run_i, cand_s, cand_i, top_k: int):
    """Merge candidates into the running top-k. All (Tq, ·) fp32/int32."""
    comb_s = jnp.concatenate([run_s, cand_s], axis=1)
    comb_i = jnp.concatenate([run_i, cand_i], axis=1)
    new_s, new_i = [], []
    for _ in range(top_k):
        m = jnp.max(comb_s, axis=1, keepdims=True)          # (Tq, 1)
        hit = comb_s == m
        first = (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1) & hit
        idx = jnp.sum(jnp.where(first, comb_i, 0), axis=1)
        new_s.append(m[:, 0])
        new_i.append(idx)
        comb_s = jnp.where(first, EXTRACTED, comb_s)
    return jnp.stack(new_s, axis=1), jnp.stack(new_i, axis=1)


def _flash_topk_kernel(q_ref, c_ref, idx_ref, s_run, i_run, *,
                       top_k: int, block_size: int, cent_tile: int,
                       n_blocks: int, n_cent_tiles: int, q_tile: int,
                       causal: bool, q_pos_offset: int):
    ct = pl.program_id(2)

    @pl.when(ct == 0)
    def _init():
        s_run[...] = jnp.full_like(s_run, INIT)
        i_run[...] = jnp.zeros_like(i_run)

    q = q_ref[0].astype(jnp.float32)                       # (Tq, d)
    cents = c_ref[0].astype(jnp.float32)                   # (C, d)
    s = jax.lax.dot_general(q, cents, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Tq, C)

    qt = pl.program_id(1)
    qpos = (qt * q_tile + q_pos_offset
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, cent_tile), 0))
    cand = (ct * cent_tile
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, cent_tile), 1))
    own = qpos // block_size
    valid = cand < n_blocks
    if causal:
        s = jnp.where(cand > own, NEG_INF, s)
        s = jnp.where((cand == own) & valid, POS_INF, s)
    s = jnp.where(valid, s, NEG_INF)

    ns, ni = _topk_update(s_run[...], i_run[...], s, cand, top_k)
    s_run[...] = ns
    i_run[...] = ni

    @pl.when(ct == n_cent_tiles - 1)
    def _emit():
        final = jnp.where(s_run[...] <= NEG_INF / 2, n_blocks, i_run[...])
        idx_ref[0] = final.astype(jnp.int32)


def flash_topk(q: jax.Array, centroids: jax.Array, top_k: int,
               block_size: int, *, group: int = 1,
               num_q_heads: int = 0, causal: bool = True,
               q_pos_offset: int = 0, q_tile: int = 128,
               cent_tile: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """q: (BH, Nq, d); centroids: (BKV, nb, d) where the leading dims are
    flattened (batch · heads) and BH = batch*H, BKV = batch*Hkv,
    H = Hkv*group.  ``num_q_heads`` is H (defaults to BH: single batch).

    Returns (BH, Nq, top_k) int32 selected block ids (sentinel nb).
    """
    interpret = resolve_interpret(interpret)
    bh, nq, d = q.shape
    bkv, nb, _ = centroids.shape
    h = num_q_heads or bh
    assert bh // h * (h // group) == bkv
    q_tile = min(q_tile, nq)
    assert nq % q_tile == 0, (nq, q_tile)
    n_cent_tiles = -(-nb // cent_tile)
    pad = n_cent_tiles * cent_tile - nb
    if pad:
        centroids = jnp.pad(centroids, ((0, 0), (0, pad), (0, 0)))

    def kv_index(hh, qt, ct):
        return ((hh // h) * (h // group) + (hh % h) // group, ct, 0)

    kernel = functools.partial(
        _flash_topk_kernel, top_k=top_k, block_size=block_size,
        cent_tile=cent_tile, n_blocks=nb, n_cent_tiles=n_cent_tiles,
        q_tile=q_tile, causal=causal, q_pos_offset=q_pos_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq // q_tile, n_cent_tiles),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda hh, qt, ct: (hh, qt, 0)),
            pl.BlockSpec((1, cent_tile, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_tile, top_k),
                               lambda hh, qt, ct: (hh, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq, top_k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((q_tile, top_k), jnp.float32),
                        pltpu.VMEM((q_tile, top_k), jnp.int32)],
        interpret=interpret,
    )(q, centroids)
