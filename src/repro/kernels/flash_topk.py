"""Pallas kernel: Flash TopK (paper Alg. 3, TPU adaptation).

Streams tiles of Q against tiles of the centroid matrix K̃ and maintains a
running per-query top-k (scores, block ids) in VMEM scratch — the full
(Nq × nb) score matrix never exists in HBM.

Two grids (DESIGN.md §2):

* ``grouped`` (default, MXU-shaped): grid (B·Hkv, Nq/Tq, ct) — the q
  block covers all G query heads of a GQA group (heads are contiguous
  per kv head in the (B·H) layout), so ONE centroid-tile DMA serves the
  whole group (1/G of the flat grid's centroid traffic) and the score
  matmul is a single (G·Tq, d) · (d, C) MXU product.  The running
  top-k is maintained by a **two-stage merge**: a tile-local top-k of
  the C candidate lanes via a bitonic tournament (sort kp-lane groups,
  then fold halves keeping each pair's top kp — O(log(C/kp)·log kp)
  compare-exchange stages), then one (k ∪ k) bitonic merge against the
  running list — replacing the flat grid's O(k·(k+C)) per-tile k-pass
  extraction.  The merge lists are padded to ``kp`` lanes (power of
  two, at least the sublane grain).
* ``flat`` (legacy, kept selectable for bisection): grid
  (B·H, Nq/Tq, ct), per-query-head centroid DMAs, and the original
  k-pass masked max-extraction (one VPU-wide max + compare per pass
  with a cumsum tie-break).

Both grids break score ties toward the lower block id — exactly
``jax.lax.top_k``'s order — so results are bit-identical to the oracle.

Selection semantics (must match `repro.core.routing.select_blocks`):
  * future blocks masked to −inf
  * own block forced to +inf (always selected, counts toward k)
  * slots with score ≤ −inf/2 are sentinels (block id = nb)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret
from repro.kernels.tiling import SUBLANE, check_topk_tiling, next_pow2

NEG_INF = -1e30       # mask level (matches core.routing)
EXTRACTED = -2e30     # strictly below mask level: never re-picked as valid
INIT = -3e30
POS_INF = 1e30


# ------------------------------------------------- bitonic lane primitives
def _cmp_halves(s, i, width):
    """One compare-exchange stage: within each lane group of ``width``,
    compare lane j against lane j + width/2 and put the greater element
    (score desc, block id asc on ties — `lax.top_k`'s order) on the
    left.  All (R, L) fp32/int32."""
    r, ln = s.shape
    half = width // 2
    s4 = s.reshape(r, ln // width, 2, half)
    i4 = i.reshape(r, ln // width, 2, half)
    a_s, b_s, a_i, b_i = s4[:, :, 0], s4[:, :, 1], i4[:, :, 0], i4[:, :, 1]
    a_wins = (a_s > b_s) | ((a_s == b_s) & (a_i < b_i))
    s = jnp.stack([jnp.where(a_wins, a_s, b_s),
                   jnp.where(a_wins, b_s, a_s)], axis=2).reshape(r, ln)
    i = jnp.stack([jnp.where(a_wins, a_i, b_i),
                   jnp.where(a_wins, b_i, a_i)], axis=2).reshape(r, ln)
    return s, i


def _flip_second_half(s, i, width):
    """Reverse the trailing half of each lane group of ``width`` so two
    descending-sorted halves become one bitonic (valley) group."""
    r, ln = s.shape
    half = width // 2
    s4 = s.reshape(r, ln // width, 2, half)
    i4 = i.reshape(r, ln // width, 2, half)
    s = jnp.stack([s4[:, :, 0], s4[:, :, 1, ::-1]], axis=2).reshape(r, ln)
    i = jnp.stack([i4[:, :, 0], i4[:, :, 1, ::-1]], axis=2).reshape(r, ln)
    return s, i


def _bitonic_merge_desc(s, i, width):
    """Sort each bitonic lane group of ``width`` descending
    (log2(width) compare-exchange stages)."""
    w = width
    while w >= 2:
        s, i = _cmp_halves(s, i, w)
        w //= 2
    return s, i


def _sort_desc(s, i, width):
    """Sort each lane group of ``width`` (a power of two) descending."""
    w = 2
    while w <= width:
        s, i = _flip_second_half(s, i, w)
        s, i = _bitonic_merge_desc(s, i, w)
        w *= 2
    return s, i


def _local_topk(s, i, kp):
    """Stage 1: tile-local top-kp of the C candidate lanes.  Sorts
    kp-lane groups descending, then a bitonic tournament folds the
    group count in half each round, keeping each merged pair's top kp.
    s (R, C) fp32, i (R, C) int32, C and kp powers of two."""
    r, c = s.shape
    if c < kp:
        s = jnp.concatenate(
            [s, jnp.full((r, kp - c), INIT, s.dtype)], axis=1)
        i = jnp.concatenate(
            [i, jnp.zeros((r, kp - c), i.dtype)], axis=1)
        c = kp
    s, i = _sort_desc(s, i, kp)
    while c > kp:
        s, i = _flip_second_half(s, i, 2 * kp)
        s, i = _bitonic_merge_desc(s, i, 2 * kp)
        s = s.reshape(r, c // (2 * kp), 2, kp)[:, :, 0].reshape(r, c // 2)
        i = i.reshape(r, c // (2 * kp), 2, kp)[:, :, 0].reshape(r, c // 2)
        c //= 2
    return s, i


def _merge_topk(run_s, run_i, loc_s, loc_i):
    """Stage 2: (k ∪ k) merge — both lists descending-sorted, so
    run ++ reverse(loc) is bitonic and one merge pass sorts it; the
    top kp lanes are the new running list."""
    kp = run_s.shape[1]
    s = jnp.concatenate([run_s, loc_s[:, ::-1]], axis=1)
    i = jnp.concatenate([run_i, loc_i[:, ::-1]], axis=1)
    s, i = _bitonic_merge_desc(s, i, 2 * kp)
    return s[:, :kp], i[:, :kp]


# ------------------------------------------------------------ legacy merge
def _topk_update(run_s, run_i, cand_s, cand_i, top_k: int):
    """Merge candidates into the running top-k. All (Tq, ·) fp32/int32.
    Legacy flat-grid path: k masked max-extraction passes over the
    (running ∪ candidate) tile."""
    comb_s = jnp.concatenate([run_s, cand_s], axis=1)
    comb_i = jnp.concatenate([run_i, cand_i], axis=1)
    new_s, new_i = [], []
    for _ in range(top_k):
        m = jnp.max(comb_s, axis=1, keepdims=True)          # (Tq, 1)
        hit = comb_s == m
        first = (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1) & hit
        idx = jnp.sum(jnp.where(first, comb_i, 0), axis=1)
        new_s.append(m[:, 0])
        new_i.append(idx)
        comb_s = jnp.where(first, EXTRACTED, comb_s)
    return jnp.stack(new_s, axis=1), jnp.stack(new_i, axis=1)


# ----------------------------------------------------------------- kernels
def _flash_topk_kernel(q_ref, c_ref, idx_ref, s_run, i_run, *,
                       top_k: int, block_size: int, cent_tile: int,
                       n_blocks: int, n_cent_tiles: int, q_tile: int,
                       causal: bool, q_pos_offset: int):
    """Legacy flat grid (B·H, Nq/Tq, ct): per-query-head centroid DMAs
    and the k-pass extraction merge."""
    ct = pl.program_id(2)

    @pl.when(ct == 0)
    def _init():
        s_run[...] = jnp.full_like(s_run, INIT)
        i_run[...] = jnp.zeros_like(i_run)

    q = q_ref[0].astype(jnp.float32)                       # (Tq, d)
    cents = c_ref[0].astype(jnp.float32)                   # (C, d)
    s = jax.lax.dot_general(q, cents, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Tq, C)

    qt = pl.program_id(1)
    qpos = (qt * q_tile + q_pos_offset
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, cent_tile), 0))
    cand = (ct * cent_tile
            + jax.lax.broadcasted_iota(jnp.int32, (q_tile, cent_tile), 1))
    own = qpos // block_size
    valid = cand < n_blocks
    if causal:
        s = jnp.where(cand > own, NEG_INF, s)
        s = jnp.where((cand == own) & valid, POS_INF, s)
    s = jnp.where(valid, s, NEG_INF)

    ns, ni = _topk_update(s_run[...], i_run[...], s, cand, top_k)
    s_run[...] = ns
    i_run[...] = ni

    @pl.when(ct == n_cent_tiles - 1)
    def _emit():
        final = jnp.where(s_run[...] <= NEG_INF / 2, n_blocks, i_run[...])
        idx_ref[0] = final.astype(jnp.int32)


def _flash_topk_kernel_grouped(q_ref, c_ref, idx_ref, s_run, i_run, *,
                               top_k: int, kp: int, block_size: int,
                               cent_tile: int, n_blocks: int,
                               n_cent_tiles: int, q_tile: int, group: int,
                               causal: bool, q_pos_offset: int):
    """Grouped grid (B·Hkv, Nq/Tq, ct): one centroid-tile DMA serves all
    G query heads; scores are one (G·Tq, d)·(d, C) MXU matmul; the
    running top-k updates through the two-stage bitonic merge."""
    ct = pl.program_id(2)

    @pl.when(ct == 0)
    def _init():
        s_run[...] = jnp.full_like(s_run, INIT)
        i_run[...] = jnp.zeros_like(i_run)

    rows = group * q_tile
    d = q_ref.shape[-1]
    q = q_ref[...].astype(jnp.float32).reshape(rows, d)    # (G·Tq, d)
    cents = c_ref[0].astype(jnp.float32)                   # (C, d)
    s = jax.lax.dot_general(q, cents, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rows, C)

    qt = pl.program_id(1)
    # the query position depends only on the row's index inside Tq —
    # every head of the group shares it
    qpos = (qt * q_tile + q_pos_offset
            + jax.lax.broadcasted_iota(
                jnp.int32, (group, q_tile, cent_tile), 1
            ).reshape(rows, cent_tile))
    cand = (ct * cent_tile
            + jax.lax.broadcasted_iota(jnp.int32, (rows, cent_tile), 1))
    own = qpos // block_size
    valid = cand < n_blocks
    if causal:
        s = jnp.where(cand > own, NEG_INF, s)
        s = jnp.where((cand == own) & valid, POS_INF, s)
    s = jnp.where(valid, s, NEG_INF)

    loc_s, loc_i = _local_topk(s, cand, kp)
    ns, ni = _merge_topk(s_run[...], i_run[...], loc_s, loc_i)
    s_run[...] = ns
    i_run[...] = ni

    @pl.when(ct == n_cent_tiles - 1)
    def _emit():
        final = jnp.where(s_run[...] <= NEG_INF / 2, n_blocks, i_run[...])
        idx_ref[...] = final.reshape(
            group, q_tile, kp)[:, :, :top_k].astype(jnp.int32)


# ----------------------------------------------------------------- wrapper
def flash_topk(q: jax.Array, centroids: jax.Array, top_k: int,
               block_size: int, *, group: int = 1,
               num_q_heads: int = 0, causal: bool = True,
               q_pos_offset: int = 0, q_tile: int = 128,
               cent_tile: int = 128, grid: str = "grouped",
               interpret: bool | None = None) -> jax.Array:
    """q: (BH, Nq, d); centroids: (BKV, nb, d) where the leading dims are
    flattened (batch · heads) and BH = batch*H, BKV = batch*Hkv,
    H = Hkv*group.  ``num_q_heads`` is H (defaults to BH: single batch).

    ``grid`` selects the grouped (B·Hkv, Nq/Tq, ct) MXU grid (default)
    or the legacy per-query-head ``flat`` grid.  Returns
    (BH, Nq, top_k) int32 selected block ids (sentinel nb).
    """
    if grid not in ("grouped", "flat"):
        raise ValueError(f"unknown topk grid {grid!r}: "
                         f"expected 'grouped' or 'flat'")
    interpret = resolve_interpret(interpret)
    bh, nq, d = q.shape
    bkv, nb, _ = centroids.shape
    h = num_q_heads or bh
    assert bh // h * (h // group) == bkv
    q_tile = min(q_tile, nq)
    assert nq % q_tile == 0, (nq, q_tile)
    n_cent_tiles = -(-nb // cent_tile)
    pad = n_cent_tiles * cent_tile - nb
    if pad:
        centroids = jnp.pad(centroids, ((0, 0), (0, pad), (0, 0)))

    if grid == "flat":
        def kv_index(hh, qt, ct):
            return ((hh // h) * (h // group) + (hh % h) // group, ct, 0)

        kernel = functools.partial(
            _flash_topk_kernel, top_k=top_k, block_size=block_size,
            cent_tile=cent_tile, n_blocks=nb, n_cent_tiles=n_cent_tiles,
            q_tile=q_tile, causal=causal, q_pos_offset=q_pos_offset)
        return pl.pallas_call(
            kernel,
            grid=(bh, nq // q_tile, n_cent_tiles),
            in_specs=[
                pl.BlockSpec((1, q_tile, d),
                             lambda hh, qt, ct: (hh, qt, 0)),
                pl.BlockSpec((1, cent_tile, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, q_tile, top_k),
                                   lambda hh, qt, ct: (hh, qt, 0)),
            out_shape=jax.ShapeDtypeStruct((bh, nq, top_k), jnp.int32),
            scratch_shapes=[pltpu.VMEM((q_tile, top_k), jnp.float32),
                            pltpu.VMEM((q_tile, top_k), jnp.int32)],
            interpret=interpret,
        )(q, centroids)

    # grouped grid: dim 0 enumerates (batch, kv head) — the G query
    # heads of a group are contiguous rows of q, and the dim-0 block
    # index b·Hkv + kv is exactly the centroid row
    if cent_tile & (cent_tile - 1):
        raise ValueError(
            f"grouped flash_topk needs a power-of-two cent_tile (the "
            f"bitonic tournament folds candidate lanes in halves); got "
            f"{cent_tile}")
    if not interpret:
        check_topk_tiling(cent_tile, q_tile, d, q.dtype)
    kp = max(SUBLANE, next_pow2(top_k))   # merge lists padded to the
    #                                       sublane grain / power of two
    kernel = functools.partial(
        _flash_topk_kernel_grouped, top_k=top_k, kp=kp,
        block_size=block_size, cent_tile=cent_tile, n_blocks=nb,
        n_cent_tiles=n_cent_tiles, q_tile=q_tile, group=group,
        causal=causal, q_pos_offset=q_pos_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh // group, nq // q_tile, n_cent_tiles),
        in_specs=[
            pl.BlockSpec((group, q_tile, d),
                         lambda gg, qt, ct: (gg, qt, 0)),
            pl.BlockSpec((1, cent_tile, d),
                         lambda gg, qt, ct: (gg, ct, 0)),
        ],
        out_specs=pl.BlockSpec((group, q_tile, top_k),
                               lambda gg, qt, ct: (gg, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq, top_k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((group * q_tile, kp), jnp.float32),
                        pltpu.VMEM((group * q_tile, kp), jnp.int32)],
        interpret=interpret,
    )(q, centroids)
