"""Pallas kernel: fused paged MoBA decode — scalar-prefetched page gather.

The serving engine's decode step used to gather the selected pages with
XLA (`core.moba.moba_paged_decode_attention`): routing, a (B,Hkv,G,1,k,
ps,d) gather materialized in HBM, then attention over the copy.  This
kernel removes the materialized gather: the per-(sequence, kv head,
slot) **physical page id** — block-table indirection resolved on the
selected pages only — is scalar-prefetched and drives the K/V
`BlockSpec` index_map (the DESIGN.md §2 trick applied to the block
table, §5), so the compute units read each selected page exactly once,
streamed straight from the pool.  An online-softmax accumulator in
scratch merges the pages, replacing the XLA lse-merge.

Two grids:

* ``grouped`` (default, MXU-shaped, DESIGN.md §5): grid (B·Hkv, U) over
  the **deduplicated union** of the pages any query head of the GQA
  group selected (U = G·top_k slots, unique pages compacted to the
  front, tail slots revisit page 0 so their DMA is elided).  Each step
  is one (G, ps)×(ps, d) pair of matmuls — a real MXU tile once G and
  ps are padded to the (8, 128) sublane×lane grain — with per-head
  (G, 1) online-softmax accumulators in VMEM.  Per-head page
  membership is expressed through a (G, U) table of token offsets whose
  non-member rows point past ``kv_len``, so masking alone reproduces
  per-query-head routing exactly.
* ``flat`` (legacy): grid (B·H, top_k), one (1, ps) VPU product per
  query head per step.  Kept for A/B benchmarking and as the shape
  oracle for the grouped grid.

Routing (centroid scores → forced own page → top-k) runs in the wrapper
with `core.moba.moba_paged_route` — scalar-prefetch indices must exist
before kernel launch — and touches only the (B·npg·Hkv·d) centroid
gather.  Realized HBM traffic per decode step is therefore
O(N/B·d) routing + O(U·ps·d) attention per kv head (U ≤ G·k, and just k
when the group's heads agree), with no densified intermediate: the
memory-bound decode shape the paper's small-block regime needs
(FlashMoBA, Table "kernel"; PAPERS.md decode-bottleneck).

Compiled lowering (``interpret=False``, see `kernels.runtime`) requires
(8, 128)-tileable pages: ``page_size`` a multiple of the dtype sublane
grain and ``head_dim`` a multiple of 128 — enforced by explicit
asserts; interpret mode accepts any shape (CPU CI runs the small test
geometries there).

Equivalence: same selection (shared router) and same softmax up to
fp32 reduction order → matches the XLA path within 1e-3
(tests/test_backends.py) on ragged batches, through both grids.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MoBAConfig
from repro.core.moba import moba_paged_route
from repro.kernels.runtime import resolve_interpret
from repro.kernels.tiling import (  # noqa: F401  (re-exported names)
    LANE,
    SUBLANE,
    check_decode_tiling,
    round_up as _round_up,
    sublane as _sublane,
)

NEG_INF = -1e30


def union_pages(idx: jax.Array, sel_valid: jax.Array, npg: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Deduplicate the GQA group's page selection per (batch, kv head).

    idx/sel_valid: (B, Hkv, G, 1, k) from `moba_paged_route`.  Returns
    ``(union, n_uniq)`` with ``union`` (B, Hkv, U) int32 logical page
    ids — unique pages sorted ascending and compacted to the front,
    U = G·k, padding slots 0 — and ``n_uniq`` (B, Hkv) the number of
    valid entries.  Shared with `benchmarks/decode_micro.py`, whose
    per-path HBM-bytes accounting integrates ``n_uniq``.
    """
    b, hkv, g, _, tk = idx.shape
    cap = g * tk
    ids = jnp.where(sel_valid, idx, npg).reshape(b, hkv, cap)
    s = jnp.sort(ids, axis=-1)
    first = jnp.concatenate(
        [jnp.ones((b, hkv, 1), bool), s[..., 1:] != s[..., :-1]], axis=-1)
    uniq = first & (s < npg)
    rank = jnp.cumsum(uniq, axis=-1) - 1
    tgt = jnp.where(uniq, rank, cap)             # cap == drop slot
    union = jnp.zeros((b, hkv, cap + 1), jnp.int32)
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(hkv)[None, :, None]
    union = union.at[bi, hi, tgt].set(s.astype(jnp.int32))
    return union[..., :cap], jnp.sum(uniq, axis=-1).astype(jnp.int32)


# ------------------------------------------------------------ grouped grid
def _decode_kernel_grouped(phys_ref, kvl_ref, ksc_ref, vsc_ref, q_ref,
                           base_ref, k_ref, v_ref, o_ref, o_acc, m_acc,
                           l_acc, *,
                           page_size: int, n_union: int, scale: float):
    """Grid (B·Hkv, U): one union page per step, (G, ps) MXU matmul.

    ``phys`` is scalar-prefetched and already drove the K/V index_map;
    ``base`` is the per-(head, slot) token offset of the page — sentinel
    npg·ps for heads that did not select it, so every token of the row
    masks out; ``kvl`` the per-row valid length; ``ksc``/``vsc`` the
    per-(row, slot) fp32 dequant scale of the streamed page (all-ones
    for unquantized pools) — the int8/fp8 tile is upcast and scaled in
    VMEM right here, before the MXU matmul, so HBM only ever moved the
    low-precision payload.  Accumulators are per-head (G, 1) VMEM tiles
    (G padded to the sublane grain)."""
    bh = pl.program_id(0)
    uu = pl.program_id(1)

    @pl.when(uu == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0].astype(jnp.float32)              # (Gp, d)
    kb = k_ref[0, :, 0, :].astype(jnp.float32) * ksc_ref[bh, uu]  # (ps, d)
    vb = v_ref[0, :, 0, :].astype(jnp.float32) * vsc_ref[bh, uu]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Gp, ps)
    s = s * scale
    base = base_ref[0, :, :]                      # (Gp, 1) int32
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    mask = pos < kvl_ref[bh]                      # (Gp, ps)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_acc[...]                           # (Gp, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.maximum(m_cur, NEG_INF / 2)      # all-masked guard
    alpha = jnp.exp(m_prev - m_safe)
    p = jnp.exp(s - m_safe) * mask.astype(jnp.float32)
    m_acc[...] = m_cur
    l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_acc[...] = (o_acc[...] * alpha
                  + jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(uu == n_union - 1)
    def _emit():
        l = l_acc[...]
        o_ref[0] = (o_acc[...]
                    / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def _decode_grouped(q, pages_k, pages_v, block_table, kv_len, idx,
                    sel_valid, *, scale: float, interpret: bool,
                    scales_k=None, scales_v=None):
    b, h, _, d = q.shape
    num_pages, ps, hkv, _ = pages_k.shape
    npg = block_table.shape[1]
    g = h // hkv
    tk = idx.shape[-1]
    cap = g * tk

    union, n_uniq = union_pages(idx, sel_valid, npg)         # (B,Hkv,U)
    tbl = jnp.maximum(block_table, 0)
    phys = tbl[jnp.arange(b)[:, None, None], union]
    phys = jnp.clip(phys, 0, num_pages - 1)

    # per-(row, union-slot) dequant scales, gathered alongside the page
    # ids the index_map prefetches (ones when the pool is unquantized —
    # multiplying by 1.0 is a bitwise no-op on the fp32 tile)
    if scales_k is None:
        ksc_f = jnp.ones((b * hkv, cap), jnp.float32)
        vsc_f = ksc_f
    else:
        hsel = jnp.arange(hkv)[None, :, None]
        ksc_f = scales_k[phys, hsel].reshape(b * hkv, cap)
        vsc_f = scales_v[phys, hsel].reshape(b * hkv, cap)

    # per-(head, union-slot) token offsets: page base where the head
    # selected the page, else the npg*ps sentinel (>= kv_len by the
    # engine's pool invariant) so the whole (1, ps) row masks out —
    # masking alone reproduces per-query-head routing on a group tile
    ids_g = jnp.where(sel_valid, idx, npg)[:, :, :, 0, :]    # (B,Hkv,G,k)
    member = (ids_g[:, :, :, :, None]
              == union[:, :, None, None, :]).any(axis=3)     # (B,Hkv,G,U)
    member &= (jnp.arange(cap)[None, None, None, :]
               < n_uniq[:, :, None, None])
    base = jnp.where(member, (union * ps)[:, :, None, :], npg * ps)

    # pad the group dim to the q-dtype sublane grain so the q block,
    # scratch and output are whole (sublane, 128) tiles; padded rows
    # carry the sentinel offset, so they mask out and emit zeros
    gp = _round_up(g, _sublane(q.dtype))
    q_f = jnp.zeros((b * hkv, gp, d), q.dtype)
    q_f = q_f.at[:, :g].set(q[:, :, 0, :].reshape(b * hkv, g, d))
    base_f = jnp.full((b * hkv, gp, cap), npg * ps, jnp.int32)
    base_f = base_f.at[:, :g].set(base.reshape(b * hkv, g, cap))
    phys_f = phys.reshape(b * hkv, cap).astype(jnp.int32)
    kvl_f = jnp.broadcast_to(kv_len[:, None], (b, hkv)).reshape(-1)
    kvl_f = kvl_f.astype(jnp.int32)

    def kv_index(bh, uu, phys_ref, kvl_ref, ksc_ref, vsc_ref):
        return (phys_ref[bh, uu], 0, bh % hkv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b * hkv, cap),
        in_specs=[
            pl.BlockSpec((1, gp, d), lambda bh, uu, *_: (bh, 0, 0)),
            pl.BlockSpec((1, gp, 1), lambda bh, uu, *_: (bh, 0, uu)),
            pl.BlockSpec((1, ps, 1, d), kv_index),
            pl.BlockSpec((1, ps, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, gp, d), lambda bh, uu, *_: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel_grouped, page_size=ps,
                               n_union=cap, scale=float(scale))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, gp, d), jnp.float32),
        interpret=interpret,
    )(phys_f, kvl_f, ksc_f, vsc_f, q_f, base_f, pages_k, pages_v)
    return out[:, :g].reshape(b, h, 1, d).astype(q.dtype)


# --------------------------------------------------------- flat (legacy)
def _decode_kernel_flat(phys_ref, base_ref, kvl_ref, ksc_ref, vsc_ref,
                        q_ref, k_ref, v_ref,
                        o_ref, o_acc, m_acc, l_acc, *,
                        page_size: int, top_k: int, scale: float):
    """Grid (B·H, top_k): one selected page per step, online softmax.

    phys/base/kvl/ksc/vsc are scalar-prefetched: ``phys`` already drove
    the K/V index_map; ``base`` is the page's logical token offset
    (sentinel npg·ps for unselected slots, so every token masks out);
    ``kvl`` the per-row valid length; ``ksc``/``vsc`` the page's fp32
    dequant scales (ones for unquantized pools), applied on the VMEM
    tile after the upcast.
    """
    bh = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[0, 0] = NEG_INF
        l_acc[0, 0] = 0.0

    q = q_ref[...].astype(jnp.float32)                 # (1, d)
    kb = k_ref[0, :, 0, :].astype(jnp.float32) * ksc_ref[bh, kk]  # (ps, d)
    vb = v_ref[0, :, 0, :].astype(jnp.float32) * vsc_ref[bh, kk]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, ps)
    s = s * scale
    pos = (base_ref[bh, kk]
           + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
    mask = pos < kvl_ref[bh]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_acc[0, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    m_safe = jnp.maximum(m_cur, NEG_INF / 2)           # all-masked guard
    alpha = jnp.exp(m_prev - m_safe)
    p = jnp.exp(s - m_safe) * mask.astype(jnp.float32)
    m_acc[0, 0] = m_cur
    l_acc[0, 0] = l_acc[0, 0] * alpha + jnp.sum(p)
    o_acc[...] = (o_acc[...] * alpha
                  + jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(kk == top_k - 1)
    def _emit():
        l = l_acc[0, 0]
        o_ref[...] = (o_acc[...]
                      / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def _decode_flat(q, pages_k, pages_v, block_table, kv_len, idx, sel_valid,
                 *, scale: float, interpret: bool,
                 scales_k=None, scales_v=None):
    b, h, _, d = q.shape
    num_pages, ps, hkv, _ = pages_k.shape
    npg = block_table.shape[1]
    g = h // hkv
    tk = idx.shape[-1]
    tbl = jnp.maximum(block_table, 0)
    phys = tbl[jnp.arange(b)[:, None, None, None, None], idx]
    phys = jnp.clip(phys, 0, num_pages - 1)
    # sentinel offset npg*ps puts every token of an unselected slot past
    # kv_len (engine invariant: kv_len <= npg*ps), masking the whole page
    base = jnp.where(sel_valid, idx * ps, npg * ps)

    # flatten heads: h = hkv * g with the same (b, hkv, g) order the
    # query layout uses, so bh -> kv head is (bh % h) // g
    phys_f = phys[:, :, :, 0, :].reshape(b * h, tk).astype(jnp.int32)
    base_f = base[:, :, :, 0, :].reshape(b * h, tk).astype(jnp.int32)
    kvl_f = jnp.broadcast_to(kv_len[:, None], (b, h)).reshape(-1)
    kvl_f = kvl_f.astype(jnp.int32)
    q_f = q[:, :, 0, :].reshape(b * h, d)
    if scales_k is None:
        ksc_f = jnp.ones((b * h, tk), jnp.float32)
        vsc_f = ksc_f
    else:
        hsel = jnp.arange(hkv)[None, :, None, None]
        ksc_f = scales_k[phys[:, :, :, 0, :], hsel].reshape(b * h, tk)
        vsc_f = scales_v[phys[:, :, :, 0, :], hsel].reshape(b * h, tk)

    def kv_index(bh, kk, phys_ref, base_ref, kvl_ref, ksc_ref, vsc_ref):
        return (phys_ref[bh, kk], 0, (bh % h) // g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(b * h, tk),
        in_specs=[
            pl.BlockSpec((1, d), lambda bh, kk, *_: (bh, 0)),
            pl.BlockSpec((1, ps, 1, d), kv_index),
            pl.BlockSpec((1, ps, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh, kk, *_: (bh, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel_flat, page_size=ps,
                               top_k=tk, scale=float(scale))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, d), jnp.float32),
        interpret=interpret,
    )(phys_f, base_f, kvl_f, ksc_f, vsc_f, q_f, pages_k, pages_v)
    return out.reshape(b, h, 1, d).astype(q.dtype)


# ---------------------------------------------------------------- wrapper
def moba_paged_decode_pallas(q: jax.Array, pages_k: jax.Array,
                             pages_v: jax.Array, centroids: jax.Array,
                             block_table: jax.Array, kv_len: jax.Array,
                             cfg: MoBAConfig,
                             scale: Optional[float] = None,
                             interpret: Optional[bool] = None,
                             grid: str = "grouped",
                             scales_k: Optional[jax.Array] = None,
                             scales_v: Optional[jax.Array] = None,
                             head_top_k: Optional[jax.Array] = None
                             ) -> jax.Array:
    """Drop-in for `core.moba.moba_paged_decode_attention` (same contract):

    q:           (B, H, 1, d)
    pages_k/v:   (P, page_size, Hkv, d) shared pool (one layer slot)
    centroids:   (P, Hkv, d) fp32 per-page centroid cache
    block_table: (B, npg) int32 physical page ids, -1 = unassigned
    kv_len:      (B,) int32 post-append valid lengths
    scales_k/v:  (P, Hkv) fp32 per-page dequant scales for int8/fp8
                 pools (None = unquantized); gathered per selected page
                 and scalar-prefetched, the kernels upcast + scale the
                 payload tile in VMEM before the matmuls

    ``interpret=None`` resolves through `kernels.runtime` (env var /
    TPU auto-detect); ``grid`` selects the MXU-shaped ``grouped`` grid
    (default) or the legacy per-query-head ``flat`` grid.  Routing runs
    in XLA on the centroid cache (shared `moba_paged_route`) — fp32
    regardless of pool dtype — then the fused gather+attend kernel.
    Rows with ``kv_len`` 0 (inactive slots) return zeros.
    """
    if grid not in ("grouped", "flat"):
        raise ValueError(f"unknown decode grid {grid!r}: "
                         f"expected 'grouped' or 'flat'")
    _, _, _, d = q.shape
    _, ps, _, _ = pages_k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    interpret = resolve_interpret(interpret)
    if not interpret and grid == "grouped":
        check_decode_tiling(ps, d, pages_k.dtype)

    # Per-head budgets (`head_top_k`, adaptive routing) truncate the
    # score-sorted selection inside the shared route: the flat grid sees
    # truncated slots as sentinel pages (zero tiles), the grouped grid's
    # union compaction shrinks n_uniq — real HBM-bytes savings with no
    # kernel change (DESIGN.md §8).
    idx, sel_valid = moba_paged_route(q, centroids, block_table, kv_len,
                                      cfg, page_size=ps,
                                      head_top_k=head_top_k)
    impl = _decode_grouped if grid == "grouped" else _decode_flat
    return impl(q, pages_k, pages_v, block_table, kv_len, idx, sel_valid,
                scale=scale, interpret=interpret,
                scales_k=scales_k, scales_v=scales_v)
