"""Pallas kernel: fused paged MoBA decode — scalar-prefetched page gather.

The serving engine's decode step used to gather the selected pages with
XLA (`core.moba.moba_paged_decode_attention`): routing, a (B,Hkv,G,1,k,
ps,d) gather materialized in HBM, then attention over the copy.  This
kernel removes the materialized gather: the per-(sequence, head, slot)
**physical page id** — block-table indirection resolved on the selected
pages only — is scalar-prefetched and drives the K/V `BlockSpec`
index_map (the DESIGN.md §2 trick applied to the block table, §5), so
the MXU/VPU reads each selected page exactly once, streamed straight
from the pool.  An online-softmax accumulator in scratch merges the
``top_k`` pages, replacing the XLA lse-merge.

Routing (centroid scores → forced own page → top-k) runs in the wrapper
with `core.moba.moba_paged_route` — scalar-prefetch indices must exist
before kernel launch — and touches only the (B·npg·Hkv·d) centroid
gather.  Realized HBM traffic per decode step is therefore
O(N/B·d) routing + O(k·B·d) attention per kv head, with no densified
intermediate: the memory-bound decode shape the paper's small-block
regime needs (FlashMoBA, Table "kernel"; PAPERS.md decode-bottleneck).

Equivalence: same selection (shared router) and same softmax up to
fp32 reduction order → matches the XLA path within 1e-3
(tests/test_backends.py) on ragged batches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MoBAConfig
from repro.core.moba import moba_paged_route

NEG_INF = -1e30


def _decode_kernel(phys_ref, base_ref, kvl_ref, q_ref, k_ref, v_ref,
                   o_ref, o_acc, m_acc, l_acc, *,
                   page_size: int, top_k: int, scale: float):
    """Grid (B·H, top_k): one selected page per step, online softmax.

    phys/base/kvl are scalar-prefetched: ``phys`` already drove the K/V
    index_map; ``base`` is the page's logical token offset (sentinel
    npg·ps for unselected slots, so every token masks out); ``kvl`` the
    per-row valid length.
    """
    bh = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[0, 0] = NEG_INF
        l_acc[0, 0] = 0.0

    q = q_ref[...].astype(jnp.float32)                 # (1, d)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (ps, d)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, ps)
    s = s * scale
    pos = (base_ref[bh, kk]
           + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
    mask = pos < kvl_ref[bh]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_acc[0, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    m_safe = jnp.maximum(m_cur, NEG_INF / 2)           # all-masked guard
    alpha = jnp.exp(m_prev - m_safe)
    p = jnp.exp(s - m_safe) * mask.astype(jnp.float32)
    m_acc[0, 0] = m_cur
    l_acc[0, 0] = l_acc[0, 0] * alpha + jnp.sum(p)
    o_acc[...] = (o_acc[...] * alpha
                  + jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(kk == top_k - 1)
    def _emit():
        l = l_acc[0, 0]
        o_ref[...] = (o_acc[...]
                      / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def moba_paged_decode_pallas(q: jax.Array, pages_k: jax.Array,
                             pages_v: jax.Array, centroids: jax.Array,
                             block_table: jax.Array, kv_len: jax.Array,
                             cfg: MoBAConfig,
                             scale: Optional[float] = None,
                             interpret: bool = True) -> jax.Array:
    """Drop-in for `core.moba.moba_paged_decode_attention` (same contract):

    q:           (B, H, 1, d)
    pages_k/v:   (P, page_size, Hkv, d) shared pool (one layer slot)
    centroids:   (P, Hkv, d) fp32 per-page centroid cache
    block_table: (B, npg) int32 physical page ids, -1 = unassigned
    kv_len:      (B,) int32 post-append valid lengths

    Routing in XLA on the centroid cache (shared `moba_paged_route`),
    then the fused gather+attend kernel above.  Rows with ``kv_len`` 0
    (inactive slots) return zeros.
    """
    b, h, _, d = q.shape
    num_pages, ps, hkv, _ = pages_k.shape
    npg = block_table.shape[1]
    g = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    idx, sel_valid = moba_paged_route(q, centroids, block_table, kv_len,
                                      cfg, page_size=ps)
    tk = idx.shape[-1]
    tbl = jnp.maximum(block_table, 0)
    phys = tbl[jnp.arange(b)[:, None, None, None, None], idx]
    phys = jnp.clip(phys, 0, num_pages - 1)
    # sentinel offset npg*ps puts every token of an unselected slot past
    # kv_len (engine invariant: kv_len <= npg*ps), masking the whole page
    base = jnp.where(sel_valid, idx * ps, npg * ps)

    # flatten heads: h = hkv * g with the same (b, hkv, g) order the
    # query layout uses, so bh -> kv head is (bh % h) // g
    phys_f = phys[:, :, :, 0, :].reshape(b * h, tk).astype(jnp.int32)
    base_f = base[:, :, :, 0, :].reshape(b * h, tk).astype(jnp.int32)
    kvl_f = jnp.broadcast_to(kv_len[:, None], (b, h)).reshape(-1)
    kvl_f = kvl_f.astype(jnp.int32)
    q_f = q[:, :, 0, :].reshape(b * h, d)

    def kv_index(bh, kk, phys_ref, base_ref, kvl_ref):
        return (phys_ref[bh, kk], 0, (bh % h) // g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * h, tk),
        in_specs=[
            pl.BlockSpec((1, d), lambda bh, kk, *_: (bh, 0)),
            pl.BlockSpec((1, ps, 1, d), kv_index),
            pl.BlockSpec((1, ps, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh, kk, *_: (bh, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, page_size=ps, top_k=tk,
                               scale=float(scale))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, d), jnp.float32),
        interpret=interpret,
    )(phys_f, base_f, kvl_f, q_f, pages_k, pages_v)
    return out.reshape(b, h, 1, d).astype(q.dtype)
