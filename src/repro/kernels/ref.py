"""Pure-jnp oracles for every Pallas kernel, plus the production XLA
fallback path (`moba_sparse_xla`) that shares the exact varlen layout and
tiling algorithm with the kernels but is expressed with `lax.scan` — used
for dry-run lowering and as a second oracle.

Single-(batch·head) shapes here; batching handled by callers/vmap.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import routing

NEG_INF = routing.NEG_INF


# ---------------------------------------------------------------- centroids
def centroids_ref(k: jax.Array, block_size: int) -> jax.Array:
    """k: (..., N, d) -> (..., nb, d); oracle for kernels/centroids.py."""
    return routing.block_centroids(k, block_size)


# ---------------------------------------------------------------- flash topk
def flash_topk_ref(q: jax.Array, centroids: jax.Array, top_k: int,
                   block_size: int, q_positions: jax.Array,
                   causal: bool = True) -> jax.Array:
    """q: (Nq, d), centroids: (nb, d) -> (Nq, top_k) selected block ids
    (sentinel = nb).  Oracle for kernels/flash_topk.py: materializes the
    full score matrix (exactly what the kernel avoids)."""
    scores = routing.routing_scores(q, centroids)
    return routing.select_blocks(scores, top_k, block_size, q_positions,
                                 causal=causal)


# ------------------------------------------------------------- fwd partials
class MobaPartials(NamedTuple):
    o: jax.Array   # (L, d) fp32 un-normalized partial outputs per slot
    m: jax.Array   # (L,) fp32 row max (NEG_INF for masked slots)
    l: jax.Array   # (L,) fp32 sum of exp


def moba_partials_ref(q_sorted: jax.Array, q_pos: jax.Array,
                      slot_block: jax.Array, k_blocks: jax.Array,
                      v_blocks: jax.Array, scale: float,
                      block_size: int, causal: bool = True,
                      kv_valid_len: Optional[int] = None) -> MobaPartials:
    """Oracle for the gather-and-densify forward kernel, full precision.

    q_sorted: (L, d) gathered queries; q_pos: (L,) token position (-1 pad);
    slot_block: (L,) block id (nb sentinel); k_blocks/v_blocks: (nb, B, d).
    """
    nb = k_blocks.shape[0]
    blk = jnp.minimum(slot_block, nb - 1)
    kg = k_blocks[blk].astype(jnp.float32)      # (L, B, d)
    vg = v_blocks[blk].astype(jnp.float32)
    s = jnp.einsum("ld,lbd->lb", q_sorted.astype(jnp.float32), kg) * scale
    kpos = slot_block[:, None] * block_size + jnp.arange(block_size)[None]
    mask = (q_pos[:, None] >= 0) & (slot_block[:, None] < nb)
    if causal:
        mask &= kpos <= q_pos[:, None]
    if kv_valid_len is not None:
        mask &= kpos < kv_valid_len
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None]) * mask
    l = p.sum(1)
    o = jnp.einsum("lb,lbd->ld", p, vg)
    m = jnp.where(mask.any(1), m, NEG_INF)
    return MobaPartials(o, m, l)


def merge_partials(o_parts: jax.Array, m_parts: jax.Array,
                   l_parts: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Flash-style lse merge over axis -2 (the per-query `k` partials).

    o_parts (..., k, d); m/l (..., k) -> (out (..., d), lse (...,)).
    """
    m_max = jnp.max(m_parts, axis=-1)
    m_safe = jnp.maximum(m_max, NEG_INF / 2)
    w = jnp.exp(m_parts - m_safe[..., None])
    l_tot = jnp.sum(l_parts * w, axis=-1)
    o = jnp.sum(o_parts * w[..., None], axis=-2)
    out = o / jnp.maximum(l_tot, 1e-30)[..., None]
    lse = m_safe + jnp.log(jnp.maximum(l_tot, 1e-30))
    return out, lse


# -------------------------------------------------------------- bwd oracle
class MobaGrads(NamedTuple):
    dq_sorted: jax.Array  # (L, d)
    dk_blocks: jax.Array  # (nb, B, d)
    dv_blocks: jax.Array  # (nb, B, d)


def moba_bwd_ref(q_sorted, q_pos, slot_block, k_blocks, v_blocks,
                 do_sorted, lse_sorted, delta_sorted, scale: float,
                 block_size: int, causal: bool = True) -> MobaGrads:
    """Oracle for the backward kernel (recompute + per-block grads).

    lse_sorted: per-slot final logsumexp of its query's merged softmax;
    delta_sorted: per-slot rowsum(dO ∘ O) of its query.
    """
    nb = k_blocks.shape[0]
    blk = jnp.minimum(slot_block, nb - 1)
    kg = k_blocks[blk].astype(jnp.float32)
    vg = v_blocks[blk].astype(jnp.float32)
    qf = q_sorted.astype(jnp.float32)
    dof = do_sorted.astype(jnp.float32)
    s = jnp.einsum("ld,lbd->lb", qf, kg) * scale
    kpos = slot_block[:, None] * block_size + jnp.arange(block_size)[None]
    mask = (q_pos[:, None] >= 0) & (slot_block[:, None] < nb)
    if causal:
        mask &= kpos <= q_pos[:, None]
    p = jnp.where(mask, jnp.exp(s - lse_sorted[:, None]), 0.0)
    dp = jnp.einsum("ld,lbd->lb", dof, vg)
    ds = p * (dp - delta_sorted[:, None]) * scale
    dq = jnp.einsum("lb,lbd->ld", ds, kg)
    dkl = jnp.einsum("lb,ld->lbd", ds, qf)    # per-slot dK contribution
    dvl = jnp.einsum("lb,ld->lbd", p, dof)    # per-slot dV contribution
    seg = jnp.minimum(slot_block, nb)         # nb collects pad/sentinel
    dk_blocks = jax.ops.segment_sum(dkl, seg, num_segments=nb + 1)[:-1]
    dv_blocks = jax.ops.segment_sum(dvl, seg, num_segments=nb + 1)[:-1]
    return MobaGrads(dq, dk_blocks, dv_blocks)


# ------------------------------------------------- production XLA fallback
def moba_sparse_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                    cfg: MoBAConfig,
                    q_positions: Optional[jax.Array] = None,
                    scale: Optional[float] = None,
                    tile: int = 128, tile_chunk: int = 8,
                    use_scan: bool = True) -> jax.Array:
    """Gather-and-densify MoBA in pure XLA with the same layout/tiling as
    the Pallas kernel — O(N·k·B) FLOPs, memory bounded by `lax.scan` over
    tile chunks.  Differentiable (jax AD through the scan).

    ``use_scan=False`` vectorizes over all tiles at once (more memory, but
    XLA cost_analysis counts scan bodies only once — the dry-run needs the
    unrolled form for faithful FLOP accounting).

    q (B,H,Nq,d); k,v (B,Hkv,N,d).
    """
    b, h, nq, d = q.shape
    _, hkv, n, _ = k.shape
    g = h // hkv
    bs = cfg.block_size
    nb = -(-n // bs)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_positions is None:
        q_positions = jnp.arange(nq) + (n - nq)
    tile = min(tile, nq)

    from repro.core.moba import moba_selection
    sel = moba_selection(q, k, cfg, q_positions)   # (B,H,Nq,k) — no grad
    sel = jax.lax.stop_gradient(sel)

    kb = routing.pad_to_blocks(k, bs, axis=-2).reshape(b, hkv, nb, bs, d)
    vb = routing.pad_to_blocks(v, bs, axis=-2).reshape(b, hkv, nb, bs, d)

    def one_head(qh, selh, kbh, vbh):
        """qh (Nq,d), selh (Nq,k), kbh/vbh (nb,bs,d)."""
        lay = routing.build_varlen_layout(selh, nq, nb, tile)
        L = lay.q_index.shape[0]
        qi = jnp.maximum(lay.q_index, 0)
        q_sorted = qh[qi]
        q_pos = jnp.where(lay.q_index >= 0, q_positions[qi], -1)
        n_tiles = L // tile

        def chunk_fn(_, tids):
            """tids: (tile_chunk,) tile indices."""
            blk = jnp.minimum(lay.tile_block[tids], nb - 1)
            kt = kbh[blk]                      # (tc, bs, d) input dtype
            vt = vbh[blk]
            rows = tids[:, None] * tile + jnp.arange(tile)[None]
            qt = q_sorted[rows]                # (tc, tile, d)
            qp = q_pos[rows]
            sb = lay.slot_block[rows]
            # bf16 operands, f32 accumulation — no f32 input copies
            s = jnp.einsum("tqd,tbd->tqb", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            kpos = (sb[..., None] * bs
                    + jnp.arange(bs)[None, None, :])
            mask = (qp[..., None] >= 0) & (sb[..., None] < nb) & (kpos < n)
            if cfg.causal:
                mask &= kpos <= qp[..., None]
            s = jnp.where(mask, s, NEG_INF)
            m = s.max(-1)
            m_safe = jnp.maximum(m, NEG_INF / 2)
            p = jnp.exp(s - m_safe[..., None]) * mask
            l = p.sum(-1)
            o = jnp.einsum("tqb,tbd->tqd", p.astype(vt.dtype), vt,
                           preferred_element_type=jnp.float32)
            m = jnp.where(mask.any(-1), m, NEG_INF)
            return None, (o, m, l)

        if use_scan:
            n_chunks = -(-n_tiles // tile_chunk)
            pad_tiles = n_chunks * tile_chunk
            # wrapped duplicate tiles land past L and are discarded
            tids = (jnp.arange(pad_tiles) % n_tiles).reshape(
                n_chunks, tile_chunk)
            _, (o_c, m_c, l_c) = jax.lax.scan(chunk_fn, None, tids)
            o_l = o_c.reshape(pad_tiles * tile, d)[: L]
            m_l = m_c.reshape(pad_tiles * tile)[: L]
            l_l = l_c.reshape(pad_tiles * tile)[: L]
        else:
            _, (o_c, m_c, l_c) = chunk_fn(None, jnp.arange(n_tiles))
            o_l = o_c.reshape(L, d)
            m_l = m_c.reshape(L)
            l_l = l_c.reshape(L)
        # merge the k partials per query
        slots = lay.pair_slot                  # (Nq, k)
        out, _ = merge_partials(o_l[slots], m_l[slots], l_l[slots])
        return out.astype(qh.dtype)

    # nested vmap keeps (batch, head) dims separate so SPMD sharding over
    # batch (dp) and heads (tp) survives without reshapes/collectives.
    kbg = jnp.repeat(kb, g, axis=1)      # (B, H, nb, bs, d)
    vbg = jnp.repeat(vb, g, axis=1)
    out = jax.vmap(jax.vmap(one_head))(q, sel, kbg, vbg)
    return out
