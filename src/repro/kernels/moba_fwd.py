"""Pallas kernel: FlashMoBA forward — gather-and-densify (paper Alg. 1).

TPU adaptation (see DESIGN.md §2): queries routed to each key block are
pre-gathered into the key-block-major sorted layout (`Q_sorted`) by one XLA
take; the kernel then runs a *dense* (Tq × d) · (d × B) MXU matmul per
tile, with the key block selected by a **scalar-prefetched** per-tile block
id driving the K/V BlockSpec index_map.  Each tile emits un-normalized
partial outputs + softmax stats (o, m, l); the per-query lse-merge over its
k partials happens in the wrapper (`ops.flash_moba`).

The query's own block is part of the routed pair list (selection forces
it), so a single universal mask `key_pos <= q_pos` gives exactly MoBA
semantics: no-op for past blocks, causal inside the own block.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _fwd_kernel(tb_ref, qs_ref, qpos_ref, k_ref, v_ref,
                o_ref, m_ref, l_ref, *,
                scale: float, block_size: int, n_blocks: int,
                n_tokens: int, causal: bool):
    bh = pl.program_id(0)
    t = pl.program_id(1)
    blk = tb_ref[bh, t]

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    kb = k_ref[0, 0].astype(jnp.float32)         # (B, d)
    vb = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]                           # (Tq,) int32
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Tq, B)
    s = s * scale
    kpos = (blk * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (tq, block_size), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None]) * mask.astype(jnp.float32)
    l = jnp.sum(p, axis=1)
    o = jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    any_valid = jnp.max(mask.astype(jnp.float32), axis=1)
    o_ref[0] = o
    m_ref[0] = jnp.where(any_valid > 0, m, NEG_INF)
    l_ref[0] = l


def moba_fwd(tile_block: jax.Array, q_sorted: jax.Array, q_pos: jax.Array,
             k_blocks: jax.Array, v_blocks: jax.Array, *,
             scale: float, block_size: int, n_tokens: int,
             num_q_heads: int, group: int, causal: bool = True,
             q_tile: int = 128, interpret: bool | None = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run the forward kernel over flattened (batch·head) layouts.

    tile_block (BH, T) int32; q_sorted (BH, L, d); q_pos (BH, L) int32;
    k_blocks/v_blocks (BKV, nb, B, d) with BKV = BH / group per batch —
    i.e. BH = batch*H, BKV = batch*Hkv, H = Hkv*group.

    Returns (o_partial (BH, L, d) f32, m (BH, L) f32, l (BH, L) f32).
    """
    interpret = resolve_interpret(interpret)
    bh, L, d = q_sorted.shape
    bkv, nb, bs, _ = k_blocks.shape
    n_tiles = L // q_tile
    assert L % q_tile == 0 and tile_block.shape == (bh, n_tiles)
    h = num_q_heads

    def kv_index(bhi, t, tb_ref):
        kv = (bhi // h) * (h // group) + (bhi % h) // group
        blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
        return (kv, blk, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_tiles),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            pl.BlockSpec((1, 1, bs, d), kv_index),
            pl.BlockSpec((1, 1, bs, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
        ],
    )
    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_size=block_size, n_blocks=nb,
        n_tokens=n_tokens, causal=causal)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, L), jnp.float32),
            jax.ShapeDtypeStruct((bh, L), jnp.float32),
        ],
        interpret=interpret,
    )(tile_block, q_sorted, q_pos, k_blocks, v_blocks)
