"""Pallas kernel: FlashMoBA forward — gather-and-densify (paper Alg. 1).

TPU adaptation (see DESIGN.md §2): queries routed to each key block are
pre-gathered into the key-block-major sorted layout (`Q_sorted`) by one XLA
take; the kernel then runs dense MXU matmuls per tile, with the key block
selected by a **scalar-prefetched** per-tile block id driving the K/V
BlockSpec index_map.  Each tile emits un-normalized partial outputs +
softmax stats (o, m, l); the per-query lse-merge over its k partials
happens in the wrapper (`ops.flash_moba`).

Two grids:

* ``grouped`` (default, kb-tiled): grid (BH, T, nkb) with a third
  dimension over ``kb_tile``-wide chunks of the key block.  The K/V
  BlockSpec streams (kb_tile, d) slices — Pallas double-buffers the
  DMAs across consecutive kb steps — and the online-softmax merge is
  carried *inside* the kernel across kb-tiles in (Tq, d)/(Tq, 1) VMEM
  scratch, so K/V DMA granularity is decoupled from ``block_size`` and
  large-block configs no longer force block-sized VMEM residency.
* ``flat`` (legacy, kept selectable for bisection): grid (BH, T) with
  whole-(B, d) K/V blocks per step.

The query's own block is part of the routed pair list (selection forces
it), so a single universal mask `key_pos <= q_pos` gives exactly MoBA
semantics: no-op for past blocks, causal inside the own block.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret
from repro.kernels.tiling import check_moba_tiling, default_kb_tile

NEG_INF = -1e30


def _fwd_kernel(tb_ref, qs_ref, qpos_ref, k_ref, v_ref,
                o_ref, m_ref, l_ref, *,
                scale: float, block_size: int, n_blocks: int,
                n_tokens: int, causal: bool):
    """Legacy flat grid: one whole key block per step."""
    bh = pl.program_id(0)
    t = pl.program_id(1)
    blk = tb_ref[bh, t]

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    kb = k_ref[0, 0].astype(jnp.float32)         # (B, d)
    vb = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]                           # (Tq,) int32
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Tq, B)
    s = s * scale
    kpos = (blk * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (tq, block_size), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None]) * mask.astype(jnp.float32)
    l = jnp.sum(p, axis=1)
    o = jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    any_valid = jnp.max(mask.astype(jnp.float32), axis=1)
    o_ref[0] = o
    m_ref[0] = jnp.where(any_valid > 0, m, NEG_INF)
    l_ref[0] = l


def _fwd_kernel_tiled(tb_ref, qs_ref, qpos_ref, k_ref, v_ref,
                      o_ref, m_ref, l_ref, o_acc, m_acc, l_acc, *,
                      scale: float, block_size: int, kb_tile: int,
                      n_kb: int, n_blocks: int, n_tokens: int,
                      causal: bool):
    """kb-tiled grid (BH, T, nkb): streams (kb_tile, d) K/V slices and
    carries the online-softmax merge across kb steps in VMEM scratch.
    The (o, m, l) output windows depend only on (bh, t), so they stay
    resident across a tile's kb run and are written once at the last
    kb step."""
    bh = pl.program_id(0)
    t = pl.program_id(1)
    kb = pl.program_id(2)
    blk = tb_ref[bh, t]

    @pl.when(kb == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = qs_ref[0].astype(jnp.float32)            # (Tq, d)
    kbt = k_ref[0, 0].astype(jnp.float32)        # (kb_tile, d)
    vbt = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[0]                           # (Tq,) int32
    tq = q.shape[0]

    s = jax.lax.dot_general(q, kbt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = (blk * block_size + kb * kb_tile
            + jax.lax.broadcasted_iota(jnp.int32, (tq, kb_tile), 1))
    mask = (qpos[:, None] >= 0) & (blk < n_blocks) & (kpos < n_tokens)
    if causal:
        mask &= kpos <= qpos[:, None]
    s = jnp.where(mask, s, NEG_INF)

    # online-softmax merge into the running (o, m, l).  With every lane
    # masked, m stays exactly NEG_INF and alpha = exp(NEG_INF - m_safe)
    # underflows to 0, so empty chunks contribute nothing.
    m_prev = m_acc[...]                                       # (Tq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.maximum(m_cur, NEG_INF / 2)
    alpha = jnp.exp(m_prev - m_safe)
    p = jnp.exp(s - m_safe) * mask.astype(jnp.float32)        # (Tq, kbt)
    m_acc[...] = m_cur
    l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    o_acc[...] = (o_acc[...] * alpha
                  + jax.lax.dot_general(p, vbt, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(kb == n_kb - 1)
    def _emit():
        l = l_acc[...]
        o_ref[0] = o_acc[...]
        m_ref[0] = jnp.where(l[:, 0] > 0, m_acc[:, 0], NEG_INF)
        l_ref[0] = l[:, 0]


def moba_fwd(tile_block: jax.Array, q_sorted: jax.Array, q_pos: jax.Array,
             k_blocks: jax.Array, v_blocks: jax.Array, *,
             scale: float, block_size: int, n_tokens: int,
             num_q_heads: int, group: int, causal: bool = True,
             q_tile: int = 128, kb_tile: int = 0, grid: str = "grouped",
             interpret: bool | None = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run the forward kernel over flattened (batch·head) layouts.

    tile_block (BH, T) int32; q_sorted (BH, L, d); q_pos (BH, L) int32;
    k_blocks/v_blocks (BKV, nb, B, d) with BKV = BH / group per batch —
    i.e. BH = batch*H, BKV = batch*Hkv, H = Hkv*group.

    ``grid`` selects the kb-tiled ``grouped`` grid (default) or the
    legacy ``flat`` grid; ``kb_tile`` (grouped only, 0 = auto
    ``min(block_size, 128)``) sets the K/V streaming granularity.

    Returns (o_partial (BH, L, d) f32, m (BH, L) f32, l (BH, L) f32).
    """
    if grid not in ("grouped", "flat"):
        raise ValueError(f"unknown moba_fwd grid {grid!r}: "
                         f"expected 'grouped' or 'flat'")
    interpret = resolve_interpret(interpret)
    bh, L, d = q_sorted.shape
    bkv, nb, bs, _ = k_blocks.shape
    n_tiles = L // q_tile
    assert L % q_tile == 0 and tile_block.shape == (bh, n_tiles)
    h = num_q_heads

    out_shape = [
        jax.ShapeDtypeStruct((bh, L, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, L), jnp.float32),
        jax.ShapeDtypeStruct((bh, L), jnp.float32),
    ]

    if grid == "flat":
        def kv_index(bhi, t, tb_ref):
            kv = (bhi // h) * (h // group) + (bhi % h) // group
            blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
            return (kv, blk, 0, 0)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, n_tiles),
            in_specs=[
                pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
                pl.BlockSpec((1, 1, bs, d), kv_index),
                pl.BlockSpec((1, 1, bs, d), kv_index),
            ],
            out_specs=[
                pl.BlockSpec((1, q_tile, d), lambda bhi, t, tb: (bhi, t, 0)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
                pl.BlockSpec((1, q_tile), lambda bhi, t, tb: (bhi, t)),
            ],
        )
        kernel = functools.partial(
            _fwd_kernel, scale=scale, block_size=block_size, n_blocks=nb,
            n_tokens=n_tokens, causal=causal)
        return pl.pallas_call(
            kernel, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(tile_block, q_sorted, q_pos, k_blocks, v_blocks)

    kb_tile = min(kb_tile or default_kb_tile(bs), bs)
    if not interpret:
        check_moba_tiling(bs, kb_tile, q_tile, d, k_blocks.dtype)
    assert bs % kb_tile == 0, (bs, kb_tile)
    n_kb = bs // kb_tile

    def kv_index(bhi, t, kb, tb_ref):
        kv = (bhi // h) * (h // group) + (bhi % h) // group
        blk = jnp.minimum(tb_ref[bhi, t], nb - 1)
        return (kv, blk * n_kb + kb, 0, 0)

    # expose the kb_tile slices as their own dim so the BlockSpec block
    # is exactly one DMA'd slice — Pallas overlaps the next slice's
    # fetch with the current step's compute (double buffering)
    k_t = k_blocks.reshape(bkv, nb * n_kb, kb_tile, d)
    v_t = v_blocks.reshape(bkv, nb * n_kb, kb_tile, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_tiles, n_kb),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, kb, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
            pl.BlockSpec((1, 1, kb_tile, d), kv_index),
            pl.BlockSpec((1, 1, kb_tile, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile, d), lambda bhi, t, kb, tb: (bhi, t, 0)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
            pl.BlockSpec((1, q_tile), lambda bhi, t, kb, tb: (bhi, t)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile, d), jnp.float32),
            pltpu.VMEM((q_tile, 1), jnp.float32),
            pltpu.VMEM((q_tile, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _fwd_kernel_tiled, scale=scale, block_size=block_size,
        kb_tile=kb_tile, n_kb=n_kb, n_blocks=nb, n_tokens=n_tokens,
        causal=causal)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
    )(tile_block, q_sorted, q_pos, k_t, v_t)
