"""Kernel-runtime policy: interpret vs compiled Pallas lowering.

Every Pallas wrapper in ``repro.kernels`` takes ``interpret=None`` and
resolves it here, so the repo has exactly ONE switch instead of
hardcoded per-kernel defaults (DESIGN.md §5):

  1. an explicit ``interpret=`` argument (or backend ``opts`` entry)
     always wins;
  2. else the ``REPRO_PALLAS_INTERPRET`` environment variable
     (``1/true/on/interpret`` vs ``0/false/off/compiled``);
  3. else auto-detect: compiled on TPU hosts, interpret everywhere
     else — so CPU CI and a real TPU pod run the same code with no
     edits, which is the whole point of the toggle.

CLI surfaces reach the same switch through the backend registry
(``--attn-backend flash:compiled`` / ``flash:interpret``, see
``core.backends.parse_backend_spec``).
"""
from __future__ import annotations

import os
from typing import Optional

ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on", "interpret")
_FALSE = ("0", "false", "no", "off", "compiled")


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` request to a concrete bool (see module
    docstring for the precedence chain)."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env:
        raise ValueError(
            f"{ENV_VAR}={env!r}: expected one of "
            f"{', '.join(_TRUE + _FALSE)}")
    import jax
    return jax.default_backend() != "tpu"
