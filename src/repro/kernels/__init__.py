from repro.kernels import moba_decode, ops, ref  # noqa: F401
