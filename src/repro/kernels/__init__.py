from repro.kernels.runtime import ENV_VAR, resolve_interpret  # noqa: F401
from repro.kernels import moba_decode, ops, ref  # noqa: F401
