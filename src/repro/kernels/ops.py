"""jit'd public wrappers around the Pallas kernels.

`flash_moba` is the full FlashMoBA pipeline with a `jax.custom_vjp`:

  fwd:  key-block centroids (kernel) → Flash TopK (kernel) → varlen layout
        (XLA sort/cumsum — deterministic Alg. 4) → Q gather (XLA take) →
        gather-and-densify attention (kernel) → per-query lse merge
  bwd:  delta = rowsum(dO∘O) → gather to sorted layout → backward kernel
        (recompute) → segment-sum dQ, group-reduce dK/dV

Ragged query lengths (Nq not a multiple of the q tile) are padded to the
tile inside the pipeline: padded rows route to the sentinel block, so
their layout slots carry `q_pos = -1` — which the kernels already mask —
and the pad is sliced off again before returning.

``grid`` selects the MXU-tiled ``grouped`` kernel grids (default: grouped
GQA topk + kb-tiled fwd/bwd) or the legacy ``flat`` grids, kept
selectable for bisection; ``kb_tile`` sets the K/V streaming granularity
of the tiled grids (0 = auto).

Routing is non-differentiable (hard top-k; matches MoBA training
semantics) — gradients flow through attention only, which is what lets
key convolution learn clustering (paper App. B.2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoBAConfig
from repro.core import routing
from repro.kernels.runtime import resolve_interpret
from repro.kernels.tiling import round_up
from repro.kernels import ref as kref
from repro.kernels.centroids import block_centroids_kernel
from repro.kernels.flash_topk import flash_topk
from repro.kernels.moba_bwd import moba_bwd
from repro.kernels.moba_fwd import moba_fwd

NEG_INF = routing.NEG_INF


class _Meta(NamedTuple):
    block_size: int
    top_k: int
    causal: bool
    q_tile: int
    scale: float
    interpret: bool
    kb_tile: int = 0
    grid: str = "grouped"


def _build_layouts(sel: jax.Array, nq: int, nb: int, tile: int):
    """sel (BH, Nq, k) -> batched VarlenLayout."""
    return jax.vmap(
        lambda s: routing.build_varlen_layout(s, nq, nb, tile))(sel)


def _flatten_kv_blocks(k: jax.Array, block_size: int):
    b, hkv, n, d = k.shape
    nb = -(-n // block_size)
    kp = routing.pad_to_blocks(k, block_size, axis=-2)
    return kp.reshape(b * hkv, nb, block_size, d), nb


def _fwd_pipeline(q, k, v, meta: _Meta):
    b, h, nq, d = q.shape
    _, hkv, n, _ = k.shape
    g = h // hkv
    bs, tk, tile = meta.block_size, meta.top_k, meta.q_tile
    tile = min(tile, nq)
    nq_p = round_up(nq, tile)

    k_blocks, nb = _flatten_kv_blocks(k, bs)
    v_blocks, _ = _flatten_kv_blocks(v, bs)

    cents = block_centroids_kernel(
        k.reshape(b * hkv, n, d), bs, interpret=meta.interpret)

    qf = q.reshape(b * h, nq, d)
    if nq_p != nq:
        qf = jnp.pad(qf, ((0, 0), (0, nq_p - nq), (0, 0)))
    q_pos_offset = n - nq
    sel = flash_topk(qf, cents, tk, bs, group=g, num_q_heads=h,
                     causal=meta.causal, q_pos_offset=q_pos_offset,
                     q_tile=tile, grid=meta.grid,
                     interpret=meta.interpret)  # (BH, Nq_p, k)
    if nq_p != nq:
        # pad queries route to the sentinel block → q_pos = -1 slots the
        # kernels mask out
        row = jnp.arange(nq_p)[None, :, None]
        sel = jnp.where(row < nq, sel, nb)

    lay = _build_layouts(sel, nq_p, nb, tile)
    qi = jnp.maximum(lay.q_index, 0)                          # (BH, L)
    q_sorted = jnp.take_along_axis(qf, qi[..., None], axis=1)
    q_pos = jnp.where(lay.q_index >= 0, qi + q_pos_offset, -1)

    o_l, m_l, l_l = moba_fwd(
        lay.tile_block, q_sorted, q_pos.astype(jnp.int32),
        k_blocks, v_blocks, scale=meta.scale, block_size=bs,
        n_tokens=n, num_q_heads=h, group=g, causal=meta.causal,
        q_tile=tile, kb_tile=meta.kb_tile, grid=meta.grid,
        interpret=meta.interpret)

    slots = lay.pair_slot.reshape(b * h, nq_p * tk)           # (BH, Nq_p*k)
    o_parts = jnp.take_along_axis(o_l, slots[..., None], axis=1)
    m_parts = jnp.take_along_axis(m_l, slots, axis=1)
    l_parts = jnp.take_along_axis(l_l, slots, axis=1)
    out, lse = kref.merge_partials(
        o_parts.reshape(b * h, nq_p, tk, d),
        m_parts.reshape(b * h, nq_p, tk),
        l_parts.reshape(b * h, nq_p, tk))
    return out[:, :nq], lse[:, :nq], lay, q_sorted, q_pos


def _flash_moba_impl(q, k, v, meta: _Meta):
    out, _, _, _, _ = _fwd_pipeline(q, k, v, meta)
    b, h, nq, d = q.shape
    return out.reshape(b, h, nq, d).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_moba(q, k, v, meta: _Meta):
    return _flash_moba_impl(q, k, v, meta)


def _flash_moba_fwd(q, k, v, meta: _Meta):
    out, lse, lay, q_sorted, q_pos = _fwd_pipeline(q, k, v, meta)
    b, h, nq, d = q.shape
    res = (q, k, v, out, lse, lay.tile_block, lay.pair_slot, q_sorted,
           q_pos)
    return out.reshape(b, h, nq, d).astype(q.dtype), res


def _flash_moba_bwd(meta: _Meta, res, g_out):
    q, k, v, out, lse, tile_block, pair_slot, q_sorted, q_pos = res
    b, h, nq, d = q.shape
    _, hkv, n, _ = k.shape
    g = h // hkv
    bs, tk, tile = meta.block_size, meta.top_k, min(meta.q_tile, nq)
    nq_p = pair_slot.shape[1]

    k_blocks, nb = _flatten_kv_blocks(k, bs)
    v_blocks, _ = _flatten_kv_blocks(v, bs)

    do = g_out.reshape(b * h, nq, d).astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1)                        # (BH, Nq)

    # scatter per-query tensors to the sorted layout (q_pos = -1 pad and
    # sentinel slots gather row 0 but are masked inside the kernel)
    qi = jnp.maximum(q_pos - (n - nq), 0)                     # query index
    do_sorted = jnp.take_along_axis(do, qi[..., None], axis=1)
    lse_sorted = jnp.take_along_axis(lse, qi, axis=1)
    delta_sorted = jnp.take_along_axis(delta, qi, axis=1)

    dq_l, dk_bh, dv_bh = moba_bwd(
        tile_block, q_sorted, q_pos, do_sorted, lse_sorted, delta_sorted,
        k_blocks, v_blocks, scale=meta.scale, block_size=bs, n_tokens=n,
        num_q_heads=h, group=g, causal=meta.causal, q_tile=tile,
        kb_tile=meta.kb_tile, grid=meta.grid, interpret=meta.interpret)

    # dQ: gather per-pair contributions and sum over the k slots.
    slots = pair_slot.reshape(b * h, nq_p * tk)
    dq_pairs = jnp.take_along_axis(dq_l, slots[..., None], axis=1)
    dq = dq_pairs.reshape(b * h, nq_p, tk, d).sum(axis=2)[:, :nq]

    # dK/dV: zero unvisited blocks, reduce over the GQA group, un-block.
    visited = (jax.nn.one_hot(tile_block, nb + 1, dtype=jnp.float32)
               .sum(axis=1)[..., :nb] > 0)                    # (BH, nb)
    dk_bh = dk_bh * visited[..., None, None]
    dv_bh = dv_bh * visited[..., None, None]
    dk = dk_bh.reshape(b, hkv, g, nb, bs, d).sum(axis=2)
    dv = dv_bh.reshape(b, hkv, g, nb, bs, d).sum(axis=2)
    dk = dk.reshape(b, hkv, nb * bs, d)[:, :, :n]
    dv = dv.reshape(b, hkv, nb * bs, d)[:, :, :n]

    return (dq.reshape(b, h, nq, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash_moba.defvjp(_flash_moba_fwd, _flash_moba_bwd)


def flash_moba(q: jax.Array, k: jax.Array, v: jax.Array, cfg: MoBAConfig,
               q_positions: Optional[jax.Array] = None,
               scale: Optional[float] = None, q_tile: int = 128,
               kb_tile: int = 0, grid: str = "grouped",
               interpret: Optional[bool] = None) -> jax.Array:
    """FlashMoBA attention (Pallas kernel path).

    q (B,H,Nq,d); k,v (B,Hkv,N,d).  ``q_positions`` must be the contiguous
    suffix of the kv sequence (training/prefill); decode uses
    `core.moba.moba_decode_attention`.

    ``grid``: 'grouped' (default — grouped-GQA topk grid + kb-tiled
    fwd/bwd) or 'flat' (legacy seed-era grids, kept for bisection).
    ``kb_tile``: K/V streaming granularity of the tiled grids, 0 = auto
    (``min(block_size, 128)``).  Nq may be ragged (padded internally).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    meta = _Meta(cfg.block_size, cfg.top_k, cfg.causal,
                 q_tile, float(scale), resolve_interpret(interpret),
                 kb_tile, grid)
    return _flash_moba(q, k, v, meta)
