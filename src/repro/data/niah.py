"""Needle-in-a-haystack synthetic task (RULER S-NIAH analogue).

A (key, value) pair is planted at a random position in a filler context;
the prompt ends with the key and the model (or, for router-only eval, the
MoBA router) must retrieve the value / the needle's block.  Used by
benchmarks/table34_niah.py and the SNR validation.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def make_niah_batch(rng: np.random.Generator, batch: int, seq_len: int,
                    vocab_size: int, needle_len: int = 4
                    ) -> Dict[str, np.ndarray]:
    """Returns tokens (B, S), needle_pos (B,), value tokens (B, needle_len).

    Layout: [filler ... KEY VALUE ... filler ... KEY] → next tokens should
    be VALUE.  KEY is a reserved sentinel pair unlikely in filler.
    """
    key_tok = vocab_size - 1
    filler = rng.integers(0, vocab_size - 2,
                          size=(batch, seq_len)).astype(np.int32)
    pos = rng.integers(1, seq_len - 3 * needle_len - 2, size=batch)
    value = rng.integers(0, vocab_size - 2,
                         size=(batch, needle_len)).astype(np.int32)
    toks = filler.copy()
    for b in range(batch):
        toks[b, pos[b]] = key_tok
        toks[b, pos[b] + 1:pos[b] + 1 + needle_len] = value[b]
        toks[b, -1] = key_tok   # query cue at the end
    return {"tokens": toks, "needle_pos": pos.astype(np.int32),
            "value": value}


def router_retrieval_accuracy(sel_blocks: np.ndarray, needle_pos: np.ndarray,
                              block_size: int) -> float:
    """Fraction of final-position queries whose selected top-k blocks
    include the needle's block. sel_blocks: (B, k) for the last query."""
    target = needle_pos // block_size
    hit = (sel_blocks == target[:, None]).any(axis=1)
    return float(hit.mean())
