"""Deterministic, shardable, checkpointable LM data pipeline.

Synthetic corpus with learnable structure (order-2 Markov chain over the
vocab + periodic copy patterns) so small models show real loss curves and
MoBA's retrieval machinery has signal to find.  The iterator is:

  * host-shardable: host i of H draws disjoint batch slices,
  * deterministic: batch at step t is a pure function of (seed, t, host),
  * checkpointable: state is just the step counter.

This is the pattern a real cluster pipeline needs for fault-tolerant
restarts (resume at step t reproduces the exact stream).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    markov_order_states: int = 64   # # of latent states in the chain
    copy_period: int = 0            # 0 = off; else plant copy patterns


class SyntheticLM:
    """Order-1 Markov over latent states, each emitting a vocab shard."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        rng = np.random.default_rng(cfg.seed)
        s = cfg.markov_order_states
        # sparse-ish transition matrix → low entropy → learnable
        trans = rng.dirichlet(np.full(s, 0.1), size=s).astype(np.float32)
        self._trans_cdf = np.cumsum(trans, axis=1)
        self._emit_base = rng.integers(0, max(cfg.vocab_size - s, 1),
                                       size=s)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host): (local_batch, seq+1)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.host_id)
        b, t = self.local_batch, cfg.seq_len + 1
        s = cfg.markov_order_states
        states = np.zeros((b, t), np.int64)
        states[:, 0] = rng.integers(0, s, size=b)
        u = rng.random((b, t))
        for i in range(1, t):
            cdf = self._trans_cdf[states[:, i - 1]]
            states[:, i] = (u[:, i:i + 1] < cdf).argmax(axis=1)
        offs = rng.integers(0, max(s, 2), size=(b, t))
        tokens = (self._emit_base[states] + offs) % cfg.vocab_size
        if cfg.copy_period:
            # plant a needle early and a cue+copy near the end: long-range
            p = cfg.copy_period
            span = min(8, t // 8)
            src = rng.integers(1, max(t // 4, 2), size=b)
            for bi in range(b):
                seg = tokens[bi, src[bi]:src[bi] + span]
                tokens[bi, -span:] = seg
        return {"tokens": tokens.astype(np.int32)}

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed,
                "host_id": self.host_id}
