"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16)
d_ff=1408/expert vocab=163840, MoE 64e top-6 (+2 shared experts per
Moonlight-16B-A3B hf config). [hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import (AttentionConfig, ModelConfig, MoEConfig,
                                with_moba)


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=163840,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                      expert_d_ff=1408),
        attention=AttentionConfig(rope_theta=5e6),
        layer_pattern=("dense",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="moonshot-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=2,
                      expert_d_ff=32),
        layer_pattern=("dense",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
