"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544, GQA. [arXiv:2403.17297]"""
from repro.configs.base import AttentionConfig, ModelConfig, with_moba


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="internlm2-1.8b", family="dense",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=92544,
        attention=AttentionConfig(rope_theta=1e6),
        layer_pattern=("dense",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="internlm2-1.8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, layer_pattern=("dense",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
