"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408/expert
vocab=151936, MoE 60e top-4 + 4 shared. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import (AttentionConfig, ModelConfig, MoEConfig,
                                with_moba)


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=151936,
        moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                      expert_d_ff=1408),
        attention=AttentionConfig(rope_theta=1e6),
        layer_pattern=("dense",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen2-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=256,
        moe=MoEConfig(num_experts=6, top_k=2, num_shared_experts=2,
                      expert_d_ff=32),
        layer_pattern=("dense",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
