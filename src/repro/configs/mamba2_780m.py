"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD. [arXiv:2405.21060]

MoBA is **inapplicable** (no attention layers to route) — see DESIGN.md
§Arch-applicability.  The arch still runs every assigned shape natively
(linear-time scan, recurrent decode)."""
from repro.configs.base import ModelConfig, SSMConfig


def get_config(moba: bool = True, **_) -> ModelConfig:
    # `moba` accepted for registry uniformity; it is a no-op here.
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(state_size=128, head_dim=64, expand=2, conv_width=4,
                      chunk_size=256),
        layer_pattern=("ssm",), tie_embeddings=True)


def get_smoke_config(moba: bool = True) -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=1, num_kv_heads=1, d_ff=0,
        vocab_size=256,
        ssm=SSMConfig(state_size=16, head_dim=16, chunk_size=16),
        layer_pattern=("ssm",), tie_embeddings=True, dtype="float32")
