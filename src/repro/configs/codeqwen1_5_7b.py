"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416, qwen1.5 arch (MHA-equivalent kv count, no qk-norm).
[hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import AttentionConfig, ModelConfig, with_moba


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        head_dim=128, d_ff=13440, vocab_size=92416,
        attention=AttentionConfig(rope_theta=1e6),
        layer_pattern=("dense",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="codeqwen1.5-7b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, layer_pattern=("dense",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
