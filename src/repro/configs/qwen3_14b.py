"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, qk_norm, GQA. [hf:Qwen/Qwen3-8B family; head_dim=128]"""
from repro.configs.base import AttentionConfig, ModelConfig, with_moba


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen3-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=17408, vocab_size=151936,
        attention=AttentionConfig(qk_norm=True, rope_theta=1e6),
        layer_pattern=("dense",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        num_layers=2, d_model=80, num_heads=5, num_kv_heads=1, head_dim=16,
        d_ff=160, vocab_size=256,
        attention=AttentionConfig(qk_norm=True),
        layer_pattern=("dense",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
