"""seamless-m4t-medium [audio] — 12L enc + 12L dec d_model=1024 16H
(kv=16) d_ff=4096 vocab=256206, enc-dec multimodal. [arXiv:2308.11596]

The audio frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, T_src, d) to the bidirectional encoder; the decoder is
causal with cross-attention.  MoBA applies to decoder self-attn (causal)
and encoder self-attn (bidirectional variant); cross-attn stays dense."""
from repro.configs.base import ModelConfig, with_moba

NUM_AUDIO_FRAMES = 1024


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        num_layers=12, num_encoder_layers=12,
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
        d_ff=4096, vocab_size=256206,
        num_audio_frames=NUM_AUDIO_FRAMES,
        layer_pattern=("decoder",))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="seamless-smoke", family="encdec",
        num_layers=2, num_encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=256, num_audio_frames=32,
        layer_pattern=("decoder",), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
