"""Config system: dataclasses describing models, MoBA, meshes and runs.

Every assigned architecture gets one module in ``repro.configs`` exposing
``get_config() -> Config`` (the exact published shape) and
``get_smoke_config() -> Config`` (a reduced same-family config for CPU
smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoBAConfig:
    """Mixture of Block Attention hyper-parameters (Lu et al. 2025; Xiao et
    al. 2025).

    ``block_size`` is the MoBA key-block size B; ``top_k`` the number of
    selected blocks per query *including* the always-selected current block
    (matching the paper's 7/8-sparsity accounting).  ``key_conv_width`` of 0
    disables key convolution; 3/5 give the paper's kconv3/kconv5.
    """

    block_size: int = 128
    top_k: int = 8
    key_conv_width: int = 0
    # Selection scores use raw q·k̃ (paper); attention uses 1/sqrt(d).
    causal: bool = True

    def validate(self) -> None:
        assert self.block_size > 0 and self.top_k > 0
        assert self.key_conv_width in (0, 2, 3, 4, 5, 7)


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Per-layer attention behaviour.

    ``kind``: 'dense' | 'swa' | 'moba'.  ``pattern`` in ModelConfig decides
    which layers use which kind (paper interleaves swa/moba).
    """

    kind: str = "dense"
    window: int = 256  # for swa
    moba: Optional[MoBAConfig] = None
    use_rope: bool = True
    rope_on_moba: bool = True  # paper's hybrid uses NoPE on MoBA layers
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # softmax scale override; None -> 1/sqrt(head_dim)
    scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    num_shared_experts: int = 0
    expert_d_ff: int = 0  # per-expert hidden size
    router_aux_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyper-parameters."""

    state_size: int = 128
    head_dim: int = 64
    num_heads: int = 0        # derived if 0: d_inner / head_dim
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # derived if 0: d_model / num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192
    tie_embeddings: bool = False
    rms_norm_eps: float = 1e-6
    # attention layout: a repeating pattern of per-layer attention kinds,
    # e.g. ("swa", "moba"). Length must divide num_layers.
    attention: AttentionConfig = dataclasses.field(
        default_factory=AttentionConfig)
    layer_pattern: Tuple[str, ...] = ("dense",)
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    ssm: SSMConfig = dataclasses.field(default_factory=SSMConfig)
    # hybrid (zamba2-style): pattern entries may be "ssm" as well.
    # encdec:
    num_encoder_layers: int = 0
    encoder_bidirectional_moba: bool = True
    # vlm: insert one cross-attn layer every `cross_attn_every` layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    # audio frontend stub
    num_audio_frames: int = 0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Logical sharding strategy knobs."""

    fsdp: bool = True              # shard params/opt over data axes (ZeRO-3)
    tensor_parallel: bool = True   # Megatron TP over "model"
    expert_parallel: bool = True   # MoE experts over "model"
    sequence_parallel: bool = False  # shard long KV over data axes (decode CP)
    remat: str = "dots"            # none | dots | full
    grad_compression: str = "none"  # none | int8


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch_size: int = 8
    seq_len: int = 512
    learning_rate: float = 6e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 = no gradient accumulation
    seed: int = 0
    checkpoint_dir: str = ""
    save_interval: int = 200
    keep_checkpoints: int = 3


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    kv_len: int = 4096
    prefill_chunk: int = 0


@dataclasses.dataclass(frozen=True)
class Config:
    model: ModelConfig
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig)
    sharding: ShardingConfig = dataclasses.field(
        default_factory=ShardingConfig)
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)


def with_moba(cfg: ModelConfig, block_size: int = 128, top_k: int = 8,
              key_conv_width: int = 0) -> ModelConfig:
    """Return a copy of ``cfg`` with its full-attention layers switched to
    MoBA (the paper's technique), leaving swa/ssm/cross layers untouched."""
    moba = MoBAConfig(block_size=block_size, top_k=top_k,
                      key_conv_width=key_conv_width)
    attn = dataclasses.replace(cfg.attention, kind="moba", moba=moba)
    pattern = tuple("moba" if p == "dense" else p for p in cfg.layer_pattern)
    return dataclasses.replace(cfg, attention=attn, layer_pattern=pattern)


# The four assigned LM shapes (seq_len, global_batch, kind).
ASSIGNED_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
