"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256, cross-attn image layers every 5th layer
(20 cross + 80 self). [hf:meta-llama/Llama-3.2-11B-Vision family]

Vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (B, n_img, d_model) consumed by the cross-attn layers.  MoBA
applies to the 80 self-attn layers; cross-attn stays dense (short image
memory)."""
from repro.configs.base import AttentionConfig, ModelConfig, with_moba

NUM_IMAGE_TOKENS = 1601


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=128256,
        num_image_tokens=NUM_IMAGE_TOKENS,
        attention=AttentionConfig(rope_theta=5e5),
        layer_pattern=("dense", "dense", "dense", "dense", "cross"))
    return with_moba(cfg, block_size, top_k, key_conv_width) if moba else cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="llama-vision-smoke", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_image_tokens=8,
        layer_pattern=("dense", "cross"), dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
