"""The paper's own 1B model — 24L hidden=2048 32H head_dim=64
intermediate=8192, Llama-2 tokenizer (32K vocab), 8K context. (paper §5.1)"""
from repro.configs.base import (AttentionConfig, MoBAConfig, ModelConfig)


def get_config(block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0, dense_baseline: bool = False
               ) -> ModelConfig:
    moba = MoBAConfig(block_size=block_size, top_k=top_k,
                      key_conv_width=key_conv_width)
    return ModelConfig(
        name=f"moba-1b-B{block_size}"
             + (f"-kconv{key_conv_width}" if key_conv_width else "")
             + ("-dense" if dense_baseline else ""),
        family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=8192, vocab_size=32000, max_seq_len=8192,
        attention=AttentionConfig(kind="moba", moba=moba, window=256,
                                  rope_on_moba=False),
        layer_pattern=("swa", "dense") if dense_baseline
        else ("swa", "moba"))


def get_smoke_config(**kw) -> ModelConfig:
    moba = MoBAConfig(block_size=16, top_k=2)
    return ModelConfig(
        name="moba-1b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        attention=AttentionConfig(kind="moba", moba=moba, window=32,
                                  rope_on_moba=False),
        layer_pattern=("swa", "moba"), dtype="float32")
