"""Architecture registry: ``--arch <id>`` ids → config modules."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401  (public re-exports)
    ASSIGNED_SHAPES, AttentionConfig, Config, MeshConfig, MoBAConfig,
    ModelConfig, MoEConfig, ServeConfig, ShardingConfig, SSMConfig,
    TrainConfig, with_moba)

# assigned architectures (10) + the paper's own models (2)
ARCHS = {
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen3-14b": "qwen3_14b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mamba2-780m": "mamba2_780m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "zamba2-1.2b": "zamba2_1_2b",
    "moba-340m": "moba_340m",
    "moba-1b": "moba_1b",
}

ASSIGNED = [a for a in ARCHS if not a.startswith("moba-")]


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, **kw) -> ModelConfig:
    return _module(arch).get_config(**kw)


def get_smoke_config(arch: str, **kw) -> ModelConfig:
    return _module(arch).get_smoke_config(**kw)
