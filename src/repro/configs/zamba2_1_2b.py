"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

Layout: 2 groups × (18 mamba2 blocks + 1 shared-weight attention block)
= 38 layers; the attention block's parameters are a single shared copy
(zamba2's signature trick).  MoBA applies to the shared attention block."""
from repro.configs.base import ModelConfig, SSMConfig, with_moba

_PATTERN = ("ssm",) * 9 + ("shared_attn",) + ("ssm",) * 9


def get_config(moba: bool = True, block_size: int = 128, top_k: int = 8,
               key_conv_width: int = 0) -> ModelConfig:
    cfg = ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=8192, vocab_size=32000,
        ssm=SSMConfig(state_size=64, head_dim=64, expand=2, conv_width=4,
                      chunk_size=256),
        layer_pattern=_PATTERN, tie_embeddings=True)
    if moba:
        cfg = with_moba(cfg, block_size, top_k, key_conv_width)
        # shared_attn resolves to attention.kind — switch it to moba
        import dataclasses
        attn = dataclasses.replace(cfg.attention, kind="moba")
        cfg = dataclasses.replace(cfg, attention=attn)
    return cfg


def get_smoke_config(moba: bool = True) -> ModelConfig:
    cfg = ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        ssm=SSMConfig(state_size=16, head_dim=16, chunk_size=16),
        layer_pattern=("ssm", "shared_attn"), tie_embeddings=True,
        dtype="float32")
    return with_moba(cfg, 16, 2) if moba else cfg
