"""Sequence-parallel (SP) MoBA for training/prefill and context-parallel
(CP) MoBA decode — the production distribution of the paper's technique.

Why shard_map and not bare SPMD: MoBA's varlen layout is built with a sort
over each head's (query, block) pairs.  Left to GSPMD, a sequence- or
head-sharded sort triggers involuntary full rematerialization (measured:
~700 GB/device temp on qwen3-0.6b train_4k).  The TPU-native mapping is:

* **SP (train/prefill)**: queries sharded over ``model`` on the *sequence*
  dim; K/V replicated across ``model`` (cheap under GQA — K/V are the
  small side).  Routing is per-query, so every shard routes and attends
  its own queries against its full local K with ZERO collectives inside
  the attention body.  One K/V all-gather per layer is the entire SP cost.
* **CP (decode)**: the KV cache is sharded over ``model`` on the sequence
  dim.  Each shard scores its local centroids, proposes its local top-k,
  all shards agree on the global top-k from the gathered (tp·k) candidate
  scores — *centroid scores are the only cross-chip traffic* (the paper's
  insight that routing compresses K by B× becomes a comms win here) — then
  each shard attends only its locally-owned selected blocks and the
  partials lse-merge with one tiny all-gather.  Per-step traffic is
  O(nb + tp·k·(d+2)) floats instead of O(N·d) for dense CP decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoBAConfig
from repro.core import routing
from repro.distributed import sharding as shmod
from repro.kernels import ref as kref

NEG_INF = routing.NEG_INF


def _mesh_info(axis: str = "model"):
    """Active mesh + the data axes usable for batch sharding.  ``axis``
    names the SP/CP axis (``model`` on training meshes; the sharded
    serving engine may CP over its own axis) and is excluded from the
    batch axes so the two never collide."""
    mesh = shmod._ACTIVE["mesh"]
    if mesh is None or axis not in mesh.axis_names:
        return None, None
    dp = tuple(a for a in shmod.data_axes(mesh) if a != axis)
    return mesh, dp


def moba_attention_sp(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: MoBAConfig, scale: Optional[float] = None,
                      q_positions: Optional[jax.Array] = None,
                      tile: int = 128, use_scan: bool = True,
                      axis: str = "model") -> jax.Array:
    """SP MoBA: q (B,H,Nq,d) seq-sharded over ``axis``; K/V replicated."""
    b, h, nq, d = q.shape
    n = k.shape[2]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    mesh, dp = _mesh_info(axis)
    tp = mesh.shape[axis] if mesh else 1
    if mesh is None or nq % tp or nq // tp < 1:
        return kref.moba_sparse_xla(q, k, v, cfg, q_positions=q_positions,
                                    scale=scale, tile=tile,
                                    use_scan=use_scan)
    bspec = dp if b % _axes_size(mesh, dp) == 0 else None
    nq_local = nq // tp
    offset = n - nq

    # jax.checkpoint = the paper's backward-with-recomputation (Alg. 5) at
    # the XLA level: scores/probs are rebuilt tile-by-tile in the backward
    # instead of being stored by AD through the tile scan.
    @jax.checkpoint
    def local_fn(q_l, k_l, v_l):
        shard = jax.lax.axis_index(axis)
        qpos = shard * nq_local + jnp.arange(nq_local) + offset
        return kref.moba_sparse_xla(
            q_l, k_l, v_l, cfg, q_positions=qpos, scale=scale,
            tile=min(tile, nq_local), use_scan=use_scan)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, axis, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, None, axis, None), check_rep=False)
    return fn(q, k, v)


def _axes_size(mesh, axes):
    s = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        s *= mesh.shape[a]
    return s


def moba_decode_cp(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   kv_len: jax.Array, cfg: MoBAConfig,
                   scale: Optional[float] = None,
                   centroids: Optional[jax.Array] = None,
                   axis: str = "model") -> jax.Array:
    """Context-parallel MoBA decode.

    q (B,H,1,d) replicated over ``axis``; caches (B,Hkv,Nmax,d) sharded
    over ``axis`` on the sequence dim.  Distributed top-k: local
    candidates → global agreement → local block attention → lse merge.

    Falls back to single-host decode when there is no mesh OR the cache
    layout cannot shard cleanly (``nmax`` not a multiple of shards ×
    block size) — a serving engine must degrade, not crash, on an
    awkward cache length.
    """
    b, h, _, d = q.shape
    _, hkv, nmax, _ = k_cache.shape
    bs = cfg.block_size
    g = h // hkv
    tk = cfg.top_k
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    mesh, dp = _mesh_info(axis)
    tp = mesh.shape[axis] if mesh is not None else 1
    if mesh is None or nmax % (tp * bs) != 0:
        from repro.core.moba import moba_decode_attention
        return moba_decode_attention(q, k_cache, v_cache, kv_len, cfg,
                                     scale=scale, centroids=centroids)
    bspec = dp if b % _axes_size(mesh, dp) == 0 else None
    n_local = nmax // tp
    nb_local = n_local // bs

    def local_fn(q_l, k_l, v_l, kv_len_l, cents_l):
        kv_len_s = kv_len_l.reshape(())
        shard = jax.lax.axis_index(axis)
        base = shard * n_local                       # global pos of shard
        qg = q_l.reshape(b_local(q_l), hkv, g, d).astype(jnp.float32)

        kb = k_l.reshape(-1, hkv, nb_local, bs, d).astype(jnp.float32)
        if cents_l is not None:
            # incremental centroid cache: N/B·d reads instead of N·d
            cents = cents_l.astype(jnp.float32)
        else:
            # recompute local centroids over valid positions (baseline)
            pos = (base + jnp.arange(nb_local)[:, None] * bs
                   + jnp.arange(bs)[None, :])        # (nb_l, bs)
            valid_tok = pos < kv_len_s
            denom = jnp.maximum(valid_tok.sum(-1), 1).astype(jnp.float32)
            cents = ((kb * valid_tok[None, None, :, :, None]).sum(-2)
                     / denom[None, None, :, None])   # (B,Hkv,nb_l,d)

        scores = jnp.einsum("bhgd,bhnd->bhgn", qg, cents)
        blk_start = base + jnp.arange(nb_local) * bs
        blk_valid = blk_start < kv_len_s
        own = jnp.maximum(kv_len_s - 1, 0) // bs     # global own block id
        is_own = (base // bs + jnp.arange(nb_local)) == own
        masked = jnp.where(blk_valid, scores, NEG_INF)
        masked = jnp.where(is_own, routing.POS_INF, masked)

        # local top-k candidates (block global ids + scores)
        tk_l = min(tk, nb_local)
        loc_s, loc_i = jax.lax.top_k(masked, tk_l)   # (B,Hkv,G,tk_l)
        if tk_l < tk:
            loc_s = jnp.concatenate(
                [loc_s, jnp.full(loc_s.shape[:-1] + (tk - tk_l,),
                                 NEG_INF)], -1)
            loc_i = jnp.concatenate(
                [loc_i, jnp.zeros(loc_i.shape[:-1] + (tk - tk_l,),
                                  loc_i.dtype)], -1)
        glob_i = base // bs + loc_i

        # gather candidates from all shards: tiny (tp·k scalars per head)
        all_s = jax.lax.all_gather(loc_s, axis, axis=3)   # (...,tp,tk)
        all_i = jax.lax.all_gather(glob_i, axis, axis=3)
        all_s = all_s.reshape(*loc_s.shape[:3], tp * tk)
        all_i = all_i.reshape(*loc_s.shape[:3], tp * tk)
        gtop_s, gtop_pos = jax.lax.top_k(all_s, tk)          # global top-k
        gtop_i = jnp.take_along_axis(all_i, gtop_pos, axis=-1)
        gsel_valid = gtop_s > NEG_INF / 2

        # my locally-owned selected blocks → dense local attention, others
        # masked out.  Worst case each shard attends ≤ k local blocks.
        sel_here = (gsel_valid
                    & (gtop_i >= base // bs)
                    & (gtop_i < base // bs + nb_local))      # (B,Hkv,G,tk)
        loc_blk = jnp.clip(gtop_i - base // bs, 0, nb_local - 1)

        def gather_blocks(blocks, idx):   # (nb_l,bs,d), (G,tk)
            return blocks[idx]            # (G,tk,bs,d)

        kg = jax.vmap(jax.vmap(gather_blocks))(kb, loc_blk)
        vb = v_l.reshape(-1, hkv, nb_local, bs, d).astype(jnp.float32)
        vg = jax.vmap(jax.vmap(gather_blocks))(vb, loc_blk)
        s = jnp.einsum("bhgd,bhgkld->bhgkl", qg, kg) * scale
        tok_pos = (base + loc_blk[..., None] * bs
                   + jnp.arange(bs))                          # (...,tk,bs)
        tok_valid = ((tok_pos < kv_len_s) & sel_here[..., None])
        s = jnp.where(tok_valid, s, NEG_INF)
        sf = s.reshape(*s.shape[:3], -1)                      # (B,Hkv,G,kl)
        m = sf.max(-1)
        m_safe = jnp.maximum(m, NEG_INF / 2)
        p = jnp.exp(sf - m_safe[..., None]) * (sf > NEG_INF / 2)
        l = p.sum(-1)
        o = jnp.einsum("bhgx,bhgxd->bhgd", p.reshape(s.shape).reshape(
            *s.shape[:3], -1), vg.reshape(*vg.shape[:3], -1, d))
        m = jnp.where(l > 0, m, NEG_INF)

        # merge partials across shards (tiny: d+2 floats per head)
        o_all = jax.lax.all_gather(o, axis)                # (tp,...)
        m_all = jax.lax.all_gather(m, axis)
        l_all = jax.lax.all_gather(l, axis)
        mm = jnp.max(m_all, axis=0)
        mm_safe = jnp.maximum(mm, NEG_INF / 2)
        w = jnp.exp(m_all - mm_safe[None])
        lt = jnp.maximum((l_all * w).sum(0), 1e-30)
        out = (o_all * w[..., None]).sum(0) / lt[..., None]
        return out.reshape(-1, h, 1, d).astype(q_l.dtype)

    def b_local(q_l):
        return q_l.shape[0]

    cent_spec = (P(bspec, None, axis, None) if centroids is not None
                 else P())
    if centroids is None:
        fn = shard_map(
            lambda q_l, k_l, v_l, kl: local_fn(q_l, k_l, v_l, kl, None),
            mesh=mesh,
            in_specs=(P(bspec, None, None, None),
                      P(bspec, None, axis, None),
                      P(bspec, None, axis, None),
                      P()),
            out_specs=P(bspec, None, None, None), check_rep=False)
        return fn(q, k_cache, v_cache,
                  kv_len.reshape(1).astype(jnp.int32))
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, None, axis, None),
                  P(bspec, None, axis, None),
                  P(), cent_spec),
        out_specs=P(bspec, None, None, None), check_rep=False)
    return fn(q, k_cache, v_cache, kv_len.reshape(1).astype(jnp.int32),
              centroids)
