"""Logical sharding rules: parameter-path → PartitionSpec, activation
constraints, and the production mesh axis conventions.

Axis conventions (DESIGN.md §3):
  * ``("pod","data")`` — combined DP/FSDP axis (gradients, batch, ZeRO-3)
  * ``"model"``        — TP: heads / ffn / vocab / experts

Model code calls :func:`constrain` on activations; it is a no-op outside a
mesh context, so the same code runs in single-device tests and the 512-chip
dry-run.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ShardingConfig

_ACTIVE: dict = {"mesh": None, "cfg": None}


@contextmanager
def use_mesh(mesh: Mesh, cfg: ShardingConfig):
    prev = dict(_ACTIVE)
    _ACTIVE.update(mesh=mesh, cfg=cfg)
    try:
        use = (jax.sharding.use_mesh(mesh)
               if hasattr(jax.sharding, "use_mesh") else mesh)
        with use:
            yield
    finally:
        _ACTIVE.update(prev)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_enabled() -> bool:
    mesh, cfg = _ACTIVE["mesh"], _ACTIVE["cfg"]
    return (mesh is not None and "model" in mesh.axis_names
            and (cfg is None or cfg.tensor_parallel))


def constrain(x: jax.Array, spec: Sequence[Optional[str]]) -> jax.Array:
    """Annotate activation sharding; logical names 'dp' and 'tp' resolve to
    the mesh's data axes and model axis. No-op without an active mesh."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    cfg = _ACTIVE["cfg"]
    has_model = "model" in mesh.axis_names
    use_tp = has_model and (cfg is None or cfg.tensor_parallel)
    use_sp = has_model and (cfg is None or cfg.sequence_parallel
                            or cfg.tensor_parallel)
    resolved = []
    for s in spec:
        if s == "dp":
            resolved.append(data_axes(mesh))
        elif s == "tp":
            resolved.append("model" if use_tp else None)
        elif s == "sp":
            resolved.append("model" if use_sp else None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# --------------------------------------------------------------- param rules
# path-regex → logical spec. 'fsdp' resolves to the data axes when ZeRO-3 is
# on (sharding the largest dim), 'tp' to the model axis.
_PARAM_RULES = [
    # embeddings (V, d): vocab over tp, d over fsdp
    (r"embed", ("tp", "fsdp")),
    # lm head (d, V): vocab over tp, d over fsdp
    (r"lm_head", ("fsdp", "tp")),
    # attention projections
    (r"wq$|wk$|wv$|w_qkv", ("fsdp", "tp")),     # (d_model, heads*dh)
    (r"wo$", ("tp", "fsdp")),                   # (heads*dh, d_model)
    # mlp
    (r"w_gate$|w_up$", ("fsdp", "tp")),
    (r"w_down$", ("tp", "fsdp")),
    # moe expert weights (E, d, f): experts over tp (EP), d over fsdp
    (r"experts.*w_(gate|up)$", (None, "fsdp", "tp")),
    (r"experts.*w_down$", (None, "tp", "fsdp")),
    (r"router$", ("fsdp", None)),
    # mamba2
    (r"in_proj$", ("fsdp", "tp")),
    (r"out_proj$", ("tp", "fsdp")),
    # norms / small vectors replicated
    (r"norm|scale|bias|a_log$|dt_bias$|d_skip$|conv|key_conv", None),
]


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for_param(path: str, shape, mesh: Mesh,
                   cfg: ShardingConfig) -> P:
    """Resolve a parameter path to a PartitionSpec on ``mesh``.

    Dims not divisible by the mapped axis size fall back to replication
    (e.g. vocab 50280 on a 16-way model axis) — jit input shardings,
    unlike activation constraints, require exact divisibility."""
    ndim = len(shape)
    logical = None
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            logical = spec
            break
    if logical is None:
        return P()
    dax = data_axes(mesh) if cfg.fsdp else None
    tp = "model" if (cfg.tensor_parallel and "model" in mesh.axis_names) \
        else None
    out = []
    for s in logical:
        if s == "fsdp":
            out.append(dax)
        elif s == "tp":
            out.append(tp)
        else:
            out.append(s)
    out = [None] * (ndim - len(out)) + out if ndim >= len(out) \
        else out[-ndim:]
    out = [a if (shape[i] % _axes_size(mesh, a) == 0) else None
           for i, a in enumerate(out)]
    return P(*out)


def param_specs(params, mesh: Mesh, cfg: ShardingConfig):
    """Map a param pytree to a matching tree of NamedShardings."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        specs.append(NamedSharding(
            mesh, spec_for_param(pstr, leaf.shape, mesh, cfg)))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def make_compat_mesh(shape, axes) -> Mesh:
    """Construct a mesh across JAX versions.

    ``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg)
    only exist in newer JAX; older installs get the implicit-auto mesh,
    which has identical semantics for our use (everything is Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> Mesh:
    return make_compat_mesh(cfg.shape, cfg.axes)
