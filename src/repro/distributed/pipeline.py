"""GPipe-style pipeline parallelism over a mesh axis (demonstration-grade).

Not the default strategy (scan+FSDP+TP covers the assigned shapes — see
DESIGN.md §3), but included to show how the stage schedule maps onto
``shard_map`` + ``collective_permute``: stage s holds layers
[s·L/S, (s+1)·L/S); microbatches stream through with the classic GPipe
bubble.  Works for forward/inference; training would add the reverse
schedule symmetrically.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn: Callable, params_stacked, x, mesh: Mesh,
                     axis: str = "model", num_microbatches: int = 4):
    """x (B, ...) → stage_fn applied S times, stages sharded over ``axis``.

    params_stacked: pytree with leading dim S (= mesh.shape[axis]); stage s
    keeps slice s.  Microbatch i enters stage 0 at tick i; total ticks =
    S + M − 1 (the GPipe bubble).
    """
    s_count = mesh.shape[axis]
    b = x.shape[0]
    assert b % num_microbatches == 0
    mb = x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    def per_stage(params_local, mb_local):
        # params_local: this stage's params (leading dim 1); mb_local: all
        # microbatches, only stage 0 feeds real data.
        stage = jax.lax.axis_index(axis)
        params_here = jax.tree.map(lambda p: p[0], params_local)
        ticks = s_count + num_microbatches - 1
        buf = jnp.zeros_like(mb_local[0])
        outs = jnp.zeros_like(mb_local)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if valid), others use received
            feed = jnp.where(t < num_microbatches,
                             mb_local[jnp.minimum(t, num_microbatches - 1)],
                             jnp.zeros_like(buf))
            inp = jnp.where(stage == 0, feed, buf)
            out = stage_fn(params_here, inp)
            # pass to next stage
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % s_count) for i in range(s_count)])
            # last stage records its finished microbatch (t - (S-1))
            done_idx = t - (s_count - 1)
            is_done = (stage == s_count - 1) & (done_idx >= 0)
            outs = jnp.where(
                is_done,
                outs.at[jnp.clip(done_idx, 0, num_microbatches - 1)].set(out),
                outs)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # only the last stage holds real outputs; share with all shards
        return jax.lax.psum(jnp.where(stage == s_count - 1, outs, 0.0),
                            axis)

    from jax.experimental.shard_map import shard_map
    spec_p = P(axis)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(jax.tree.map(lambda _: spec_p, params_stacked),
                             P()),
                   out_specs=P(), check_rep=False)
    outs = fn(params_stacked, mb)
    return outs.reshape(b, *x.shape[1:])
