"""Step-time heartbeat monitor — straggler detection for large jobs.

At 1000+ chips the SPMD program is a global barrier per step, so a single
slow host shows up as elongated step wall-time for *everyone*.  The
monitor keeps a rolling step-time distribution and flags:

  * **stragglers** — steps slower than ``threshold ×`` the rolling median
    (on a real cluster each host exports its own timings; the controller
    compares across hosts to localize the slow one),
  * **stalls** — no heartbeat within ``stall_timeout`` seconds, the signal
    to trigger the checkpoint-restart path (`train.py --resume auto`
    restarts from the latest atomic checkpoint, possibly elastically on a
    smaller mesh — see checkpoint/manager.py).

The response ladder on a real pod, in escalation order: (1) log + export
the flag, (2) exclude the host's data shard at the next step (input
pipeline is host-local and deterministic so this is a pure re-shard),
(3) evict the slice at the next checkpoint boundary and restart elastic.
"""
from __future__ import annotations

import collections
import statistics
import time
from typing import Callable, List, Optional


class HeartbeatMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 stall_timeout: float = 300.0,
                 on_straggler: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.window = window
        self.threshold = threshold
        self.stall_timeout = stall_timeout
        self.on_straggler = on_straggler
        self._clock = clock
        self._times = collections.deque(maxlen=window)
        self._last_beat = None
        self.straggler_steps: List[int] = []

    def beat(self, step: int) -> Optional[float]:
        """Call once per completed step; returns the step duration."""
        now = self._clock()
        if self._last_beat is None:
            self._last_beat = now
            return None
        dt = now - self._last_beat
        self._last_beat = now
        if len(self._times) >= 5:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                self.straggler_steps.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self._times.append(dt)
        return dt

    def is_stalled(self) -> bool:
        if self._last_beat is None:
            return False
        return (self._clock() - self._last_beat) > self.stall_timeout

    @property
    def median_step_time(self) -> Optional[float]:
        return statistics.median(self._times) if self._times else None

    def summary(self) -> dict:
        return {
            "steps_observed": len(self._times),
            "median_s": self.median_step_time,
            "p99_s": (sorted(self._times)[int(0.99 * (len(self._times) - 1))]
                      if self._times else None),
            "stragglers": list(self.straggler_steps),
        }
