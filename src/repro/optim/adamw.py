"""AdamW with cosine schedule, warmup, global-norm clipping.

fp32 master weights + moments; model casts to bf16 at use sites.  Matches
the paper's recipe: β=(0.9, 0.95), wd 0.1, clip 1.0, cosine to 10% peak.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def cosine_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        warm = cfg.learning_rate * (step + 1) / max(cfg.warmup_steps, 1)
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.learning_rate * (0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


_NO_DECAY = ("norm", "scale", "bias", "a_log", "dt_bias", "d_skip")


def adamw_update(params, grads, state: AdamWState, cfg: TrainConfig,
                 lr_fn=None) -> Tuple[dict, AdamWState, dict]:
    lr_fn = lr_fn or cosine_schedule(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_fn(state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_params = jax.tree_util.tree_flatten_with_path(params)
    paths = ["/".join(str(getattr(k, "key", k)) for k in path)
             for path, _ in flat_params[0]]

    def upd(path_leaf, g, m, n):
        path, p = path_leaf
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        n = b2 * n + (1 - b2) * g * g
        update = (m / c1) / (jnp.sqrt(n / c2) + 1e-8)
        if cfg.weight_decay and not any(t in path for t in _NO_DECAY):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * update).astype(p.dtype), m, n

    leaves_p = [leaf for _, leaf in flat_params[0]]
    leaves_g = jax.tree_util.tree_leaves(grads)
    leaves_m = jax.tree_util.tree_leaves(state.mu)
    leaves_n = jax.tree_util.tree_leaves(state.nu)
    out = [upd((path, p), g, m, n) for path, p, g, m, n
           in zip(paths, leaves_p, leaves_g, leaves_m, leaves_n)]
    treedef = flat_params[1]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_n = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_n), {
        "lr": lr, "grad_norm": gnorm}
