"""Gradient compression (int8 + error feedback) for cross-pod reduces.

Cuts the DP all-reduce payload 4× vs fp32 / 2× vs bf16 — the thin
inter-pod links are the binding collective at multi-pod scale (see
EXPERIMENTS.md §Roofline).  Error feedback keeps the compression unbiased
over time (Karimireddy et al. 2019 style).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array, residual: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g fp32 + carried residual -> (int8 q, scale, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_names, residuals):
    """shard_map-body helper: int8-quantize each leaf, all-reduce the int32
    payload + per-leaf scales, return (averaged grads, new residuals).

    Must be called inside shard_map with ``axis_names`` mapped.
    """
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        n *= jax.lax.psum(1, a)

    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        # agree on one scale first so every shard quantizes consistently
        s = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_names) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * s
        tot = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return (tot.astype(jnp.float32) * s / n).astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(tree)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    grads = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return grads, new_res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
