"""Model primitives: RMSNorm, RoPE, SwiGLU MLP, GQA attention layers.

Functional style: ``init_*(key, cfg) -> params`` / ``apply(params, x) -> y``
with params as plain dicts (checkpoint- and shard-friendly).  Compute in
``cfg.dtype`` (bf16 default), params in fp32; all attention math fp32.

Key-conv caching: the depthwise conv is causal, so a convolved key never
changes once written — the KV cache stores *convolved* keys plus a (W−1)-
deep ring buffer of raw keys for the single-step decode conv.  Routing and
attention therefore always see the same convolved keys (paper App. B).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import attention_dispatch
from repro.core.key_conv import (apply_key_conv, apply_key_conv_decode,
                                 init_key_conv, key_conv_state_init)
from repro.distributed.sharding import constrain, tp_enabled


def wcast(w: jax.Array, dt) -> jax.Array:
    """Cast a (possibly FSDP-sharded) weight to compute dtype and, in
    SP/FSDP mode, pin the replication AFTER the cast so SPMD all-gathers
    bf16 instead of the fp32 master (halves weight-AG bytes)."""
    w = w.astype(dt)
    if not tp_enabled():
        w = constrain(w, (None,) * w.ndim)
    return w


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, H, N, d); positions: (N,) shared or (B, N) per-sequence
    (ragged serving batches where each row sits at a different offset)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    positions = jnp.asarray(positions)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., N, d/2)
    ang = ang[None, None] if positions.ndim == 1 else ang[:, None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- mlp
def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": _dense_init(k1, (d_model, d_ff)),
            "w_up": _dense_init(k2, (d_model, d_ff)),
            "w_down": _dense_init(k3, (d_ff, d_model))}


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ wcast(p["w_gate"], dt)) * (x @ wcast(p["w_up"], dt))
    # TP mode: hidden sharded on features (Megatron); SP/FSDP mode: stay
    # sequence-sharded — replicating here costs an (B,S,d_ff) all-gather.
    h = constrain(h, ("dp", None, "tp") if tp_enabled()
                  else ("dp", "sp", None))
    out = h @ wcast(p["w_down"], dt)
    return constrain(out, ("dp", "sp", None) if not tp_enabled()
                     else ("dp", "sp", None))


# --------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig, kind: str) -> dict:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {"wq": _dense_init(ks[0], (d, h * dh)),
         "wk": _dense_init(ks[1], (d, hkv * dh)),
         "wv": _dense_init(ks[2], (d, hkv * dh)),
         "wo": _dense_init(ks[3], (h * dh, d))}
    if cfg.attention.qk_norm:
        p["q_norm_scale"] = jnp.ones((dh,), jnp.float32)
        p["k_norm_scale"] = jnp.ones((dh,), jnp.float32)
    a = cfg.attention
    if kind == "moba" and a.moba is not None and a.moba.key_conv_width:
        p["key_conv"] = init_key_conv(ks[4], a.moba.key_conv_width, hkv, dh)
    return p


def _split_heads(x, n_heads, dh):
    b, n, _ = x.shape
    return x.reshape(b, n, n_heads, dh).transpose(0, 2, 1, 3)


def _uses_rope(cfg: ModelConfig, kind: str) -> bool:
    a = cfg.attention
    if not a.use_rope or kind == "cross":
        return False
    if kind == "moba":
        return getattr(a, "rope_on_moba", True)
    return True


def apply_attention(p: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                    *, positions: Optional[jax.Array] = None,
                    cache: Optional[dict] = None,
                    backend: str = "reference",
                    cross_kv: Optional[jax.Array] = None,
                    causal: bool = True,
                    page_state: Optional[dict] = None,
                    head_top_k: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Optional[dict]]:
    """Self (or cross) attention layer.  Returns (out, updated_cache).

    ``backend`` names a registered attention backend (``core.backends``);
    every implementation choice below routes through the registry's
    capability query rather than string branches.

    The cache protocol admits two interchangeable cache kinds behind this
    one interface: the dense per-sequence cache from ``init_cache`` and
    the paged pool from ``serving.paged_cache`` (recognised by its
    ``pages_k`` leaf).  Paged caches additionally need ``page_state`` =
    {block_table (B,npg), kv_len (B,) pre-step lengths, q_len (B,) new
    tokens this step, active (B,) bool} from the scheduler.

    ``head_top_k``: optional (H,) int32 per-query-head routing budgets
    in [1, moba.top_k] from a calibrated routing profile (DESIGN.md §8).
    Only the paged MoBA paths consume it; dense/swa/cross ignore it.
    """
    dt = x.dtype
    a = cfg.attention
    b, n, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = _split_heads(x @ wcast(p["wq"], dt), h, dh)
    src = cross_kv if cross_kv is not None else x
    k = _split_heads(src @ wcast(p["wk"], dt), hkv, dh)
    v = _split_heads(src @ wcast(p["wv"], dt), hkv, dh)
    if kind == "moba" and n > 1:
        # SP layout: queries sharded on sequence, K/V replicated over
        # 'model' (see distributed/moba_sp.py)
        q = constrain(q, ("dp", None, "sp", None))
        k = constrain(k, ("dp", None, None, None))
        v = constrain(v, ("dp", None, None, None))
    else:
        q = constrain(q, ("dp", "tp", None, None))
        k = constrain(k, ("dp", "tp", None, None))

    if a.qk_norm and "q_norm_scale" in p:
        q = rms_norm(q, p["q_norm_scale"], cfg.rms_norm_eps)
        k = rms_norm(k, p["k_norm_scale"], cfg.rms_norm_eps)

    if positions is None:
        positions = (jnp.arange(n) if cache is None
                     else cache["len"] + jnp.arange(n))
    if _uses_rope(cfg, kind):
        q = apply_rope(q, positions, a.rope_theta)
        if cross_kv is None:
            k = apply_rope(k, positions, a.rope_theta)

    conv_w = p.get("key_conv") if kind == "moba" else None
    kv_len = None
    new_cache = None
    if cache is not None and "pages_k" in cache and cross_kv is None:
        o, new_cache = _paged_attend(q, k, v, cache, page_state, cfg,
                                     kind, positions, backend, conv_w,
                                     head_top_k=head_top_k)
        o = o.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
        out = o @ wcast(p["wo"], dt)
        return out, new_cache
    if cache is not None and cross_kv is None:
        if conv_w is not None:
            if n == 1:
                k, conv_state = apply_key_conv_decode(
                    conv_w, k, cache["key_conv_state"])
            else:  # prefill: conv the whole prefix, keep raw tail as state
                depth = cache["key_conv_state"].shape[2]
                raw = jnp.concatenate(
                    [cache["key_conv_state"], k.astype(
                        cache["key_conv_state"].dtype)], axis=2)
                conv_state = raw[:, :, -depth:] if depth else \
                    cache["key_conv_state"]
                k = apply_key_conv(conv_w, k)
        idx = cache["len"]
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, idx, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, idx, 0))
        new_cache = dict(cache, k=kc, v=vc, len=idx + n)
        if conv_w is not None:
            new_cache["key_conv_state"] = conv_state
        if "centroids" in cache:
            from repro.core import routing as _routing
            bs_ = cfg.attention.moba.block_size
            if n == 1:
                # one rank-1 centroid update for the written block
                j = idx // bs_
                m_in = (idx % bs_).astype(jnp.float32)
                old_c = jax.lax.dynamic_slice_in_dim(
                    cache["centroids"], j, 1, axis=2)       # (B,Hkv,1,dh)
                new_c = (old_c * m_in + k.astype(jnp.float32)) / (m_in + 1)
                new_cache["centroids"] = jax.lax.dynamic_update_slice(
                    cache["centroids"], new_c, (0, 0, j, 0))
            else:  # prefill: rebuild from the updated cache once
                new_cache["centroids"] = _routing.block_centroids(
                    kc, bs_, kv_len=idx + n).astype(jnp.float32)
        k, v = kc, vc
        kv_len = idx + n
    elif conv_w is not None:
        k = apply_key_conv(conv_w, k)

    o = attention_dispatch(a, "dense" if kind == "cross" else kind,
                           q, k, v, key_conv_weights=None,
                           q_positions=positions,
                           kv_len=kv_len, backend=backend,
                           causal=causal and cross_kv is None,
                           centroids=(new_cache or {}).get("centroids")
                           if kind == "moba" else None)
    o = o.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
    o = constrain(o, ("dp", None, "tp") if tp_enabled()
                  else ("dp", "sp", None))
    out = o @ wcast(p["wo"], dt)
    if n > 1:
        out = constrain(out, ("dp", "sp", None))
    return out, new_cache


def _paged_attend(q, k, v, cache, page_state, cfg: ModelConfig, kind: str,
                  positions, backend: str, conv_w=None, head_top_k=None):
    """Paged-cache attention: append new K/V through the block table, then
    attend via the backend resolved for (kind, phase, paged).  MoBA decode
    routes on the per-page centroid cache and reads only the selected
    pages; swa decode gathers only the window's pages; dense decode
    densifies via the table.  Prefill is ragged (right-padded rows of
    ``q_len`` valid tokens) and backend-shared (see core.backends);
    ``page_state['chunked']`` (a static bool) selects the chunk-aware
    prefill that attends through the block table to earlier chunks.

    Key-conv (``conv_w``): keys are convolved *before* the page write, so
    centroids and attention always see convolved keys, exactly like the
    dense cache.  The raw-key left context lives in the pool's per-slot
    ring buffer ``key_conv_state`` — prefill rows address it via
    ``page_state['slots']``, decode rows are the slots.  Fresh rows
    (``kv_len`` 0) read a zero state, which both matches the dense path's
    zero padding bitwise and makes recycled slots' stale state harmless.
    """
    from repro.core import backends as B
    from repro.core.key_conv import (apply_key_conv_decode,
                                     apply_key_conv_with_state,
                                     key_conv_state_update)
    from repro.serving import paged_cache as PC

    assert page_state is not None, "paged cache requires page_state"
    a = cfg.attention
    n = q.shape[2]
    bt = page_state["block_table"]
    kvl = page_state["kv_len"]
    q_len = page_state["q_len"]
    active = page_state["active"]
    post_len = kvl + q_len                     # lengths after this step
    needs_conv = conv_w is not None
    htk = None
    adaptive = head_top_k is not None and kind == "moba"
    if adaptive:
        # (H,) per-query-head budgets -> the (Hkv, G) grouped-GQA layout
        # every routing path speaks (h = hkv*G + g, `_group_queries`)
        hkv, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
        htk = jnp.asarray(head_top_k, jnp.int32).reshape(hkv, g)
    if needs_conv and "key_conv_state" not in cache:
        from repro.serving.scheduler import UnsupportedFeatureError
        raise UnsupportedFeatureError(
            "key_conv",
            "paged pool lacks the per-slot raw-key ring buffer; build "
            "caches with init_paged_caches(..., max_seqs > 0) for "
            "key-conv configs (DESIGN.md §4)")
    new_ring = None
    if n == 1:                                 # decode: one token per seq
        k_raw = k
        if needs_conv:
            ring = cache["key_conv_state"]     # decode rows ARE the slots
            k, stepped = apply_key_conv_decode(conv_w, k, ring)
            new_ring = jnp.where(active[:, None, None, None], stepped, ring)
        be = B.resolve(backend, kind=kind, phase="decode", cache="paged",
                       key_conv=needs_conv, adaptive=adaptive)
        new_cache = PC.paged_append_decode(cache, bt, kvl, active, k, v)
        if new_ring is not None:
            new_cache["key_conv_state"] = new_ring
        if needs_conv and "key_conv_tails" in cache:
            new_cache = PC.update_key_conv_tails(
                new_cache, bt, kvl, active.astype(jnp.int32), k_raw)
        o = be.paged_decode(a, kind, q, new_cache, bt, post_len,
                            positions=positions, head_top_k=htk)
        return o, new_cache
    # ragged prefill (fresh one-shot, or one chunk of a chunked prompt)
    if needs_conv:
        ring = cache["key_conv_state"]
        slots = page_state["slots"]            # (B,) row -> sequence slot
        state = ring[jnp.maximum(slots, 0)]
        fresh = (kvl == 0) | (slots < 0)
        state = jnp.where(fresh[:, None, None, None],
                          jnp.zeros_like(state), state)
        k_raw = k
        k = apply_key_conv_with_state(conv_w, k, state)
        stepped = key_conv_state_update(state, k_raw, q_len)
        write = jnp.where(active & (slots >= 0), slots, ring.shape[0])
        new_ring = ring.at[write].set(stepped.astype(ring.dtype),
                                      mode="drop")
    be = B.resolve(backend, kind=kind, phase="prefill", cache="paged",
                   key_conv=needs_conv, adaptive=adaptive)
    new_cache = PC.paged_append_prefill(cache, bt, q_len, k, v, kv_len=kvl)
    if new_ring is not None:
        new_cache["key_conv_state"] = new_ring
    if needs_conv and "key_conv_tails" in cache:
        new_cache = PC.update_key_conv_tails(new_cache, bt, kvl, q_len,
                                             k_raw)
    if page_state.get("chunked"):
        o = be.paged_chunk_prefill(a, kind, q, new_cache, bt, kvl, q_len,
                                   head_top_k=htk)
    else:
        o = be.paged_prefill(a, kind, q, k, v, post_len=post_len,
                             positions=jnp.arange(n), head_top_k=htk)
    return o, new_cache


def init_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    c = {"k": jnp.zeros((batch, hkv, max_len, dh), dtype),
         "v": jnp.zeros((batch, hkv, max_len, dh), dtype),
         "len": jnp.zeros((), jnp.int32)}
    a = cfg.attention
    if kind == "moba" and a.moba is not None:
        # incremental centroid cache: decode routing reads N/B·d instead
        # of re-reading the whole K cache (beyond-paper; EXPERIMENTS §Perf)
        nb = -(-max_len // a.moba.block_size)
        c["centroids"] = jnp.zeros((batch, hkv, nb, dh), jnp.float32)
        if a.moba.key_conv_width:
            c["key_conv_state"] = key_conv_state_init(
                a.moba.key_conv_width, batch, hkv, dh, dtype)
    return c
