"""Decoder-only LM, encoder-decoder and VLM backbones.

Layer layout is a repeating ``cfg.layer_pattern`` of slot kinds:
  dense | swa | moba          — attention block (+ MLP or MoE per family)
  ssm                         — Mamba-2 block
  shared_attn                 — zamba2-style shared-weight attention block
  cross                       — VLM cross-attention block (image memory)
  decoder                     — enc-dec layer (self + cross + MLP)

``num_layers == len(pattern) * n_groups`` and the model scans over groups
with stacked per-slot params — HLO size is O(len(pattern)), not O(layers),
which keeps 100-layer dry-run compiles fast.  `shared_attn` params are a
single (non-scanned) copy applied every group: weight sharing is exact.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE


def _block_kinds(cfg: ModelConfig):
    pattern = cfg.layer_pattern
    assert cfg.num_layers % len(pattern) == 0, (cfg.num_layers, pattern)
    return pattern, cfg.num_layers // len(pattern)


def _is_attn(kind: str) -> bool:
    return kind in ("dense", "swa", "moba", "cross", "decoder")


# ------------------------------------------------------------------ blocks
def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.ones((d,), jnp.float32)}
    if kind == "ssm":
        p["mamba"] = M.init_mamba2(ks[0], cfg)
        return p
    p["attn"] = L.init_attention(ks[0], cfg, kind)
    p["norm2"] = jnp.ones((d,), jnp.float32)
    if kind == "decoder":
        p["cross"] = L.init_attention(ks[1], cfg, "cross")
        p["norm_cross"] = jnp.ones((d,), jnp.float32)
    if cfg.family == "moe" and kind != "cross":
        p["moe"] = MOE.init_moe(ks[2], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff)
    return p


def apply_block(p: dict, x: jax.Array, cfg: ModelConfig, kind: str, *,
                positions=None, cache=None, backend="reference",
                cross_kv=None, causal=True, page_state=None,
                head_top_k=None):
    """Pre-LN block. Returns (x, aux_loss, new_cache).

    ``head_top_k``: optional (H,) int32 per-head routing budgets for this
    layer's MoBA attention (adaptive routing profile, DESIGN.md §8)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, new_cache = M.apply_mamba2(p["mamba"], L.rms_norm(
            x, p["norm1"], cfg.rms_norm_eps), cfg, cache)
        return x + h, aux, new_cache

    attn_kind = {"shared_attn": "dense", "decoder": "moba"
                 if cfg.attention.kind == "moba" else "dense"}.get(kind, kind)
    if kind == "cross":
        h, new_cache = L.apply_attention(
            p["attn"], L.rms_norm(x, p["norm1"], cfg.rms_norm_eps), cfg,
            "cross", positions=positions, cross_kv=cross_kv)
    else:
        self_cache = cache.get("self") if (kind == "decoder"
                                           and cache is not None) else cache
        h, new_cache = L.apply_attention(
            p["attn"], L.rms_norm(x, p["norm1"], cfg.rms_norm_eps), cfg,
            attn_kind, positions=positions, cache=self_cache,
            backend=backend, causal=causal, page_state=page_state,
            head_top_k=head_top_k)
    x = x + h
    if kind == "decoder":
        h, _ = L.apply_attention(
            p["cross"], L.rms_norm(x, p["norm_cross"], cfg.rms_norm_eps),
            cfg, "cross", positions=positions, cross_kv=cross_kv)
        x = x + h
        new_cache = {"self": new_cache} if new_cache is not None else None
    if "moe" in p:
        h, aux = MOE.apply_moe(
            p["moe"], L.rms_norm(x, p["norm2"], cfg.rms_norm_eps), cfg)
    elif "mlp" in p:
        h = L.apply_mlp(p["mlp"], L.rms_norm(x, p["norm2"],
                                             cfg.rms_norm_eps))
    else:
        return x, aux, new_cache
    return x + h, aux, new_cache


# ------------------------------------------------------------------- model
def init_lm(key, cfg: ModelConfig) -> dict:
    pattern, n_groups = _block_kinds(cfg)
    keys = jax.random.split(key, len(pattern) + 4)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "blocks": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab_size),
            jnp.float32) * cfg.d_model ** -0.5
    for i, kind in enumerate(pattern):
        if kind == "shared_attn":
            params.setdefault("shared", init_block(keys[i], cfg, "dense"))
            continue
        gkeys = jax.random.split(keys[i], n_groups)
        params["blocks"][f"slot_{i}"] = jax.vmap(
            lambda kk: init_block(kk, cfg, kind))(gkeys)
    if cfg.num_encoder_layers:
        ekeys = jax.random.split(keys[-3], cfg.num_encoder_layers)
        enc_kind = ("moba" if (cfg.attention.kind == "moba"
                               and cfg.encoder_bidirectional_moba)
                    else "dense")
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda kk: init_block(kk, cfg, enc_kind))(ekeys),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def apply_encoder(params, src_embeds: jax.Array, cfg: ModelConfig,
                  backend="reference", unroll: bool = False) -> jax.Array:
    """Bidirectional encoder over stub frontend embeddings (B, T, d)."""
    enc_kind = ("moba" if (cfg.attention.kind == "moba"
                           and cfg.encoder_bidirectional_moba) else "dense")
    x = src_embeds.astype(cfg.dtype)

    def body(x, p):
        x, _, _ = apply_block(p, x, cfg, enc_kind, causal=False,
                              backend=backend)
        return x, None

    if unroll:
        for li in range(cfg.num_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[li],
                                        params["encoder"]["blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return L.rms_norm(x, params["encoder"]["final_norm"], cfg.rms_norm_eps)


def lm_apply(params, tokens: jax.Array, cfg: ModelConfig, *,
             caches: Optional[dict] = None, backend: str = "reference",
             cross_kv: Optional[jax.Array] = None,
             positions: Optional[jax.Array] = None,
             remat: bool = False, unroll: bool = False,
             page_state: Optional[dict] = None,
             route_map: Optional[dict] = None):
    """tokens (B, S) -> (logits (B, S, V), aux, new_caches).

    ``unroll=True`` replaces the layer-group scan with a python loop —
    needed by the dry-run because XLA cost_analysis counts while-loop
    bodies only once (HLO grows O(layers), compile stays tractable via the
    grouped pattern).

    ``route_map``: optional ``{"slot_i": (n_groups, H) int32}`` per-head
    MoBA routing budgets from a calibrated profile (DESIGN.md §8) —
    scanned alongside params/caches so each group's layers see their own
    (H,) rows.  Slots absent from the map (non-MoBA kinds, or all slots
    under static routing) run the static ``top_k``."""
    pattern, n_groups = _block_kinds(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    # Megatron-SP residual stream: batch over dp, sequence over model —
    # remat-saved layer inputs shard 16x; SPMD all-gathers around the TP
    # matmuls (sequence length is always a model-axis multiple here).
    x = constrain(x, ("dp", "sp", None) if tokens.shape[1] > 1
                  else ("dp", None, None))

    def group_body(carry, xs):
        x, aux = carry
        gparams, gcaches, groute = xs
        new_gcaches = {}
        for i, kind in enumerate(pattern):
            p_i = (params["shared"] if kind == "shared_attn"
                   else gparams[f"slot_{i}"])
            cache_i = None if gcaches is None else gcaches.get(f"slot_{i}")
            rt_i = None if groute is None else groute.get(f"slot_{i}")
            x, a, nc = apply_block(p_i, x, cfg, kind,
                                   positions=positions, cache=cache_i,
                                   backend=backend,
                                   page_state=page_state,
                                   head_top_k=rt_i,
                                   cross_kv=cross_kv
                                   if kind in ("cross", "decoder")
                                   else None)
            if nc is not None:
                new_gcaches[f"slot_{i}"] = nc
            aux = aux + a
        return (x, aux), (new_gcaches or None)

    body = jax.checkpoint(group_body) if remat else group_body
    if unroll:
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for gi in range(n_groups):
            gp = jax.tree.map(lambda a: a[gi], params["blocks"])
            gc = (None if caches is None
                  else jax.tree.map(lambda a: a[gi], caches))
            gr = (None if route_map is None
                  else jax.tree.map(lambda a: a[gi], route_map))
            carry, y = body(carry, (gp, gc, gr))
            ys.append(y)
        (x, aux) = carry
        new_caches = (None if ys[0] is None else
                      jax.tree.map(lambda *a: jnp.stack(a), *ys))
    else:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], caches, route_map))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dt)
    # force (d replicated, vocab tp-sharded) before the matmul: SPMD then
    # all-gathers the small head slice instead of all-reducing the huge
    # (B,S,V) partial logits (measured 109 GB/device of AR without this).
    head = constrain(head, (None, "tp"))
    logits = x @ head
    logits = constrain(logits, ("dp", None, "tp"))
    return logits, aux, new_caches


def lm_loss(params, batch: dict, cfg: ModelConfig,
            backend: str = "reference", remat: bool = False,
            unroll: bool = False):
    """batch: {'tokens': (B, S+1) int32} → mean next-token CE + MoE aux."""
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    cross_kv = batch.get("cross_kv")
    if cfg.num_encoder_layers and "src_embeds" in batch:
        cross_kv = apply_encoder(params, batch["src_embeds"], cfg,
                                 backend=backend, unroll=unroll)
    logits, aux, _ = lm_apply(params, inp, cfg, backend=backend,
                              cross_kv=cross_kv, remat=remat,
                              unroll=unroll)
    # memory-frugal CE: logsumexp + target gather — never materializes an
    # fp32 (B,S,V) tensor (the convert fuses into the reduction; measured
    # 263GB -> single-digit GB per device on qwen3-0.6b train_4k).
    lse = jax.nn.logsumexp(logits, axis=-1)                  # (B,S)
    tgt_logit = jnp.take_along_axis(
        logits, tgt[..., None], axis=-1)[..., 0].astype(jnp.float32)
    ll = tgt_logit - lse.astype(jnp.float32)
    mask = batch.get("mask", jnp.ones_like(tgt, jnp.float32))
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, {"ce": loss, "aux": aux}


# -------------------------------------------------------------------- cache
def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    """Stacked (n_groups-leading) caches matching the scan layout."""
    pattern, n_groups = _block_kinds(cfg)

    def one_group(_):
        g = {}
        for i, kind in enumerate(pattern):
            if kind == "ssm":
                g[f"slot_{i}"] = M.init_mamba2_cache(cfg, batch, dtype)
            elif kind == "shared_attn":
                g[f"slot_{i}"] = L.init_cache(cfg, "dense", batch, max_len,
                                              dtype)
            elif kind == "cross":
                continue  # cross kv recomputed from image embeddings
            elif kind == "decoder":
                g[f"slot_{i}"] = {"self": L.init_cache(
                    cfg, cfg.attention.kind, batch, max_len, dtype)}
            else:
                g[f"slot_{i}"] = L.init_cache(cfg, kind, batch, max_len,
                                              dtype)
        return g

    return jax.vmap(one_group)(jnp.arange(n_groups))


def init_paged_caches(cfg: ModelConfig, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16, max_seqs: int = 0,
                      prefix_tails: bool = False,
                      kv_dtype: str = "fp32") -> dict:
    """Stacked paged caches (page pools) in the same group/slot layout as
    :func:`init_caches`, so either cache kind flows through the same scan.

    Only attention slots are pageable; recurrent (ssm) and cross/decoder
    slots have no paging granularity — the engine rejects those archs.
    ``max_seqs`` sizes the per-slot key-conv ring buffers on MoBA slots
    of key-conv models (zero skips them — dryrun/inspection use);
    ``prefix_tails`` additionally materializes the per-page raw-key
    tails prefix-cache engines restore ring state from.
    """
    from repro.serving import paged_cache as PC

    pattern, n_groups = _block_kinds(cfg)
    for kind in pattern:
        if kind not in ("dense", "swa", "moba", "shared_attn"):
            raise ValueError(
                f"paged caches support attention-only layer patterns; "
                f"got {kind!r} in {pattern}")

    def one_group(_):
        return {f"slot_{i}": PC.init_page_pool(
                    cfg, num_pages, page_size,
                    with_centroids=(kind == "moba"), dtype=dtype,
                    max_seqs=max_seqs, prefix_tails=prefix_tails,
                    kv_dtype=kv_dtype)
                for i, kind in enumerate(pattern)}

    return jax.vmap(one_group)(jnp.arange(n_groups))


def prefill(params, tokens: jax.Array, cfg: ModelConfig, caches,
            backend="reference", cross_kv=None, unroll: bool = False,
            page_state=None, positions=None, route_map=None):
    """``positions`` defaults to [0, S) (fresh prompts); chunked paged
    prefill passes per-row (B, S) offsets instead."""
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    logits, aux, new_caches = lm_apply(
        params, tokens, cfg, caches=caches, backend=backend,
        cross_kv=cross_kv, unroll=unroll, page_state=page_state,
        positions=positions, route_map=route_map)
    return logits, new_caches


def decode_step(params, token: jax.Array, cfg: ModelConfig, caches,
                backend="reference", cross_kv=None, unroll: bool = False,
                page_state=None, route_map=None):
    """token (B, 1) against caches; returns (logits (B,1,V), new_caches).

    With a paged cache the per-sequence position is the scheduler's
    pre-step length; with a dense cache it is the shared cache length."""
    if page_state is not None:
        pos = page_state["kv_len"][:, None]                  # (B,1) ragged
    else:
        pos = _cache_len(caches, cfg) + jnp.arange(1)
    logits, _, new_caches = lm_apply(
        params, token, cfg, caches=caches, backend=backend,
        cross_kv=cross_kv, positions=pos, unroll=unroll,
        page_state=page_state, route_map=route_map)
    return logits, new_caches


def _cache_len(caches, cfg: ModelConfig):
    leaves = [v for k, v in jax.tree_util.tree_flatten_with_path(caches)[0]
              if str(k[-1]) == "DictKey(key='len')" or
              (hasattr(k[-1], "key") and k[-1].key == "len")]
    return leaves[0][0] if leaves else jnp.zeros((), jnp.int32)
