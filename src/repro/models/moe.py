"""Mixture-of-Experts MLP with GShard-style capacity-based dense dispatch.

Expert-parallel friendly: expert weights carry a leading E dim sharded over
the ``model`` axis; dispatch/combine are einsums against one-hot routing
tensors, so SPMD turns them into all-to-alls on real meshes.  Load-balance
auxiliary loss follows Switch Transformer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    ks = jax.random.split(key, 7)
    scale = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale,
        "experts": {
            "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
            "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
            "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32)
            * f ** -0.5,
        },
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d, fs), jnp.float32) * scale,
            "w_up": jax.random.normal(ks[5], (d, fs), jnp.float32) * scale,
            "w_down": jax.random.normal(ks[6], (fs, d), jnp.float32)
            * fs ** -0.5,
        }
    return p


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Dense-dispatch formulation: tokens → (E, C, d) expert batches via a
    one-hot dispatch tensor (capacity C per expert), expert FFN as batched
    einsum over E, then combine weighted by router probs.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    tokens = b * s
    # exact (drop-free) dispatch for small token counts — keeps decode
    # bit-consistent with prefill; capacity dropping only at train scale.
    cap = (tokens * k if tokens <= 64
           else max(int(capacity_factor * tokens * k / e), 1))
    dt = x.dtype

    xf = x.reshape(tokens, d)
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)         # (T, k, E)
    flatoh = onehot.reshape(tokens * k, e)
    pos = (jnp.cumsum(flatoh, axis=0) - flatoh).reshape(tokens, k, e)
    pos = (pos * onehot).sum(-1)                               # (T, k)
    keep = pos < cap                                           # drop overflow
    # scatter-based dispatch: O(T·k·d), not O(T·k·C) — slot indices are
    # unique by construction so scatter-add has no collisions.  2D (E, cap)
    # destination + expert-dim sharding constraint keeps the buffer from
    # being all-reduced whole (GSPMD pads E when model-axis ∤ E).
    dst_e = jnp.where(keep, top_e, e).reshape(-1)              # (T·k,)
    dst_c = jnp.where(keep, pos, 0).reshape(-1)
    buf = jnp.zeros((e + 1, cap, d), dt)
    buf = buf.at[dst_e, dst_c].add(
        jnp.repeat(xf, k, axis=0), mode="drop")
    expert_in = constrain(buf[:-1], ("tp", None, None))

    w = p["experts"]
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in,
                                w["w_gate"].astype(dt)))
         * jnp.einsum("ecd,edf->ecf", expert_in, w["w_up"].astype(dt)))
    expert_out = jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(dt))
    expert_out = constrain(expert_out, ("tp", None, None))

    slots = expert_out[jnp.minimum(dst_e.reshape(tokens, k), e - 1),
                       dst_c.reshape(tokens, k)]
    slots = slots * keep[..., None].astype(dt)                 # (T, k, d)
    out = jnp.einsum("tk,tkd->td", top_p.astype(dt), slots)

    if m.num_shared_experts and "shared" in p:
        sh = p["shared"]
        hs = (jax.nn.silu(xf @ sh["w_gate"].astype(dt))
              * (xf @ sh["w_up"].astype(dt)))
        out = out + hs @ sh["w_down"].astype(dt)

    # Switch aux loss: E * sum_e (fraction_tokens_e * mean_prob_e)
    frac = onehot[:, :, :].astype(jnp.float32).sum((0, 1)) / (tokens * k)
    mean_p = probs.mean(0)
    aux = e * jnp.sum(frac * mean_p) * m.router_aux_coef
    return out.reshape(b, s, d), aux
