from repro.models import api, layers, mamba2, moe, transformer  # noqa: F401
