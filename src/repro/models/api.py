"""Model-level public API: step functions + dry-run input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
the given (arch config × assigned shape) cell — weak-type-correct,
shardable, no device allocation.  Modality frontends are stubs: audio
archs get precomputed frame embeddings, VLMs get patch embeddings
(per the assignment: backbone only)."""
from __future__ import annotations

import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ASSIGNED_SHAPES, ModelConfig
from repro.models import transformer as T


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: T.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE-aware: only top_k + shared experts count as active."""
    total = param_count(cfg)
    if cfg.family != "moe" or not cfg.moe.num_experts:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.expert_d_ff
    pattern, n_groups = T._block_kinds(cfg)
    n_moe_layers = sum(1 for k in pattern if k not in ("cross", "ssm")
                      ) * n_groups
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


def input_specs(cfg: ModelConfig, shape: str,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Dry-run input ShapeDtypeStructs for one assigned shape cell.

    kind 'train'   → {'tokens': (B, S+1)} (+ stub modality inputs)
    kind 'prefill' → {'tokens': (B, S)} (+ stubs)
    kind 'decode'  → {'token': (B, 1), 'caches': <pytree>} (+ stubs)
    """
    info = ASSIGNED_SHAPES[shape]
    s, b, kind = info["seq_len"], info["global_batch"], info["kind"]
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f = functools.partial(jax.ShapeDtypeStruct, dtype=dtype)

    specs: Dict[str, Any] = {}
    if kind == "train":
        specs["tokens"] = i32((b, s + 1))
    elif kind == "prefill":
        specs["tokens"] = i32((b, s))
    else:
        specs["token"] = i32((b, 1))
        caches = jax.eval_shape(
            lambda: T.init_caches(cfg, b, s, dtype=dtype))
        specs["caches"] = caches
    if cfg.family == "vlm":
        specs["cross_kv"] = f((b, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        specs["src_embeds"] = f((b, cfg.num_audio_frames, cfg.d_model))
    return specs


def make_forward(cfg: ModelConfig, backend: str = "sparse"):
    def forward(params, tokens, cross_kv=None, src_embeds=None):
        ck = cross_kv
        if cfg.num_encoder_layers and src_embeds is not None:
            ck = T.apply_encoder(params, src_embeds, cfg,
                                 backend=backend)
        logits, aux, _ = T.lm_apply(params, tokens, cfg,
                                    backend=backend, cross_kv=ck)
        return logits
    return forward
