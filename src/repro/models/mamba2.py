"""Mamba-2 (SSD — state-space duality) block, chunked-scan formulation.

Follows Dao & Gu 2024 (arXiv:2405.21060): per-head scalar decay
``a_t = exp(Δ_t · A)``, rank-1 state updates ``h_t = a_t h_{t-1} + Δ_t B_t
x_tᵀ``, outputs ``y_t = C_tᵀ h_t + D x_t``, computed chunk-parallel so all
heavy math is MXU matmuls (TPU-native: the chunked form IS the
hardware-aware adaptation — no sequential scan on the critical path except
the tiny inter-chunk carry).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def _ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.num_heads or d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.state_size


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh, hd, ns = _ssm_dims(cfg)
    conv_dim = d_inner + 2 * ns
    ks = jax.random.split(key, 5)
    return {
        # order: [z (d_inner), xBC (conv_dim), dt (nh)]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * d_inner + 2 * ns + nh), jnp.float32) * d ** -0.5,
        "conv_w": jax.random.normal(
            ks[1], (cfg.ssm.conv_width, conv_dim), jnp.float32) * 0.1,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": jax.random.normal(
            ks[2], (d_inner, d), jnp.float32) * d_inner ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """x (B, T, C), w (W, C) depthwise causal; state (B, W-1, C) raw tail."""
    width = w.shape[0]
    if state is not None:
        x_ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(x_ext[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = x_ext[:, -(width - 1):] if width > 1 else state
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """SSD chunked scan.

    x (B,T,H,P); dt (B,T,H) post-softplus; b,c (B,T,N); returns y (B,T,H,P).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    nc = t // chunk
    a = -jnp.exp(a_log)                                   # (H,) negative
    la = dt * a[None, None, :]                            # log decay (B,T,H)

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    lac = la.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    cum = jnp.cumsum(lac, axis=2)                         # (B,nc,Q,H)
    # intra-chunk: S_ij = (C_i·B_j) exp(cum_i - cum_j) dt_j  for i>=j
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)            # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])
    s = (cb[..., None] * jnp.exp(jnp.where(causal[..., None],
                                           decay, -jnp.inf))
         * dtc[:, :, None, :, :])                          # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", s, xc)

    # chunk summary state: sum_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j
    tail = cum[:, :, -1:, :] - cum                         # (B,nc,Q,H)
    contrib = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                         jnp.exp(tail) * dtc, bc, xc)      # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1])                   # (B,nc,H)

    def carry_fn(hstate, inp):
        contrib_c, decay_c = inp
        new = hstate * decay_c[..., None, None] + contrib_c
        return new, hstate                                 # emit pre-state

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, hpre = jax.lax.scan(
        carry_fn, h0,
        (contrib.swapaxes(0, 1).astype(jnp.float32),
         chunk_decay.swapaxes(0, 1).astype(jnp.float32)))
    hpre = hpre.swapaxes(0, 1)                             # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         cc, hpre.astype(cc.dtype), jnp.exp(cum))
    y = y_intra + y_inter + xc * d_skip[None, None, None, :, None]
    return y.reshape(bsz, t, h, p)


def apply_mamba2(p: dict, x: jax.Array, cfg: ModelConfig,
                 cache: Optional[dict] = None
                 ) -> Tuple[jax.Array, Optional[dict]]:
    """x (B, T, d_model) -> (y, new_cache).  Decode path (T==1) uses the
    recurrent update on the cached (H, N, P) state."""
    d_inner, nh, hd, ns = _ssm_dims(cfg)
    bsz, t, _ = x.shape
    dt_ = x.dtype

    proj = x @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * ns],
                               axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(dt_), conv_state)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])
    xh = xs.reshape(bsz, t, nh, hd)

    if cache is None or t > 1:
        chunk = min(cfg.ssm.chunk_size, t)
        pad = (-t) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        y = ssd_chunked(xh.astype(jnp.float32), dt, p["a_log"],
                        b.astype(jnp.float32), c.astype(jnp.float32),
                        p["d_skip"], chunk)[:, :t]
        new_cache = None
        if cache is not None:
            # rebuild the final recurrent state for subsequent decode
            la = dt[:, :t] * (-jnp.exp(p["a_log"]))[None, None]
            w = jnp.exp(jnp.cumsum(la[:, ::-1], axis=1)[:, ::-1] - la)
            hstate = jnp.einsum("bth,btn,bthp->bhnp",
                                w * dt[:, :t], b[:, :t].astype(jnp.float32),
                                xh[:, :t].astype(jnp.float32))
            new_cache = dict(cache, conv=new_conv, ssm=hstate,
                             len=cache["len"] + t)
    else:
        a = -jnp.exp(p["a_log"])                          # (H,)
        la = (dt[:, 0] * a[None]).astype(jnp.float32)     # (B,H)
        hprev = cache["ssm"]
        contrib = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0],
                             b[:, 0].astype(jnp.float32),
                             xh[:, 0].astype(jnp.float32))
        hstate = hprev * jnp.exp(la)[..., None, None] + contrib
        y = (jnp.einsum("bn,bhnp->bhp", c[:, 0].astype(jnp.float32), hstate)
             + xh[:, 0].astype(jnp.float32)
             * p["d_skip"][None, :, None])[:, None]
        new_cache = dict(cache, conv=new_conv, ssm=hstate,
                         len=cache["len"] + 1)

    y = y.reshape(bsz, t, d_inner).astype(dt_)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    from repro.models.layers import rms_norm
    y = rms_norm(y, p["norm_scale"], cfg.rms_norm_eps)
    y = constrain(y, ("dp", None, "tp"))
    return y @ p["out_proj"].astype(dt_), new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int,
                      dtype=jnp.bfloat16) -> dict:
    d_inner, nh, hd, ns = _ssm_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm.conv_width - 1,
                               d_inner + 2 * ns), dtype),
            "ssm": jnp.zeros((batch, nh, ns, hd), jnp.float32),
            "len": jnp.zeros((), jnp.int32)}
