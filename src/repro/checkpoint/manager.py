"""Fault-tolerant checkpointing: atomic, async, elastic.

* **Atomic**: checkpoints are written to ``<dir>/tmp.step_N`` and renamed
  to ``<dir>/step_N`` only when complete — a crash mid-save never corrupts
  the latest checkpoint.
* **Async**: saves run on a writer thread; the train loop only blocks to
  snapshot arrays to host (device_get), never on disk I/O.
* **Elastic**: arrays are stored as full logical values with a manifest of
  paths/shapes/dtypes; restore re-shards onto *any* mesh via
  ``jax.device_put(x, NamedSharding(new_mesh, spec))`` — restart on a
  different chip count works (ZeRO-3 resharding).
* Data-iterator state + RNG + step are stored alongside params so restarts
  reproduce the exact token stream.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, list]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_writes: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = None
        if async_writes:
            self._thread = threading.Thread(target=self._writer,
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False) -> None:
        """Snapshot to host and enqueue the disk write."""
        if self._err:
            raise RuntimeError("checkpoint writer failed") from self._err
        paths, leaves, _ = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        payload = (step, paths, host_leaves, extra or {})
        if self._thread is None or block:
            self._write(payload)
        else:
            self._q.put(payload)

    def wait(self) -> None:
        if self._thread is not None:
            self._q.join()
        if self._err:
            raise RuntimeError("checkpoint writer failed") from self._err

    def _writer(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            except BaseException as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, payload):
        step, paths, leaves, extra = payload
        tmp = os.path.join(self.dir, f"tmp.step_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": leaf for i, leaf in enumerate(leaves)})
        manifest = {"step": step, "paths": paths, "extra": extra,
                    "shapes": [list(np.shape(x)) for x in leaves],
                    "dtypes": [str(np.asarray(x).dtype) for x in leaves]}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, dict, int]:
        """Restore into the structure of ``template``; re-shard with
        ``shardings`` (same pytree structure) if given (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        t_paths, t_leaves, treedef = _flatten(template)
        by_path = {p: data[f"a{i}"]
                   for i, p in enumerate(manifest["paths"])}
        missing = [p for p in t_paths if p not in by_path]
        if missing:
            raise KeyError(f"checkpoint missing params: {missing[:5]}...")
        restored = []
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(t_paths))
        for p, tmpl, sh in zip(t_paths, t_leaves, shard_leaves):
            arr = by_path[p]
            if list(arr.shape) != list(tmpl.shape):
                raise ValueError(f"shape mismatch for {p}: "
                                 f"{arr.shape} vs {tmpl.shape}")
            arr = arr.astype(tmpl.dtype)
            restored.append(jax.device_put(arr, sh) if sh is not None
                            else jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, restored)
        return tree, manifest["extra"], step
